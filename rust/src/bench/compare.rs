//! Report-vs-baseline comparison: the perf-regression gate (DESIGN.md §9).
//!
//! [`compare`] diffs a fresh [`BenchReport`] against a committed baseline,
//! metric by metric, applying per-kind noise-floor thresholds
//! ([`Thresholds`]). The outcome feeds three consumers:
//!
//! - `cdnl bench compare --gate` exits nonzero when any diff
//!   [`Status::is_failure`] — the CI contract;
//! - [`CompareOutcome::table`] renders the fixed-width terminal table;
//! - [`CompareOutcome::markdown`] renders the same rows for
//!   `$GITHUB_STEP_SUMMARY`.
//!
//! Gating is scoped to what a comparison can actually prove:
//!
//! - wall-clock metrics (`time_ms`, `rate`) gate only when report and
//!   baseline carry the same host fingerprint (a laptop baseline must not
//!   fail CI on a slower runner; `--strict-host` overrides) *and* the same
//!   configuration;
//! - `stat` metrics are deterministic functions of the configuration, so
//!   they gate only when the config fingerprint, quick/full mode and
//!   backend all match;
//! - `count` metrics are structural contracts and gate everywhere, as does
//!   a metric that silently disappears from the report.
//!
//! Everything downgraded by those rules is reported as
//! [`Status::Skipped`] (advisory), never silently dropped.

use super::report::{kind, BenchReport};
use std::fmt::Write as _;

/// Per-kind noise floors. The defaults are deliberately generous: the gate
/// exists to catch *regressions*, not scheduler jitter.
#[derive(Clone, Copy, Debug)]
pub struct Thresholds {
    /// `time_ms`: fail when `new > base * (1 + time_rel_tol)` ...
    pub time_rel_tol: f64,
    /// ... AND the absolute growth exceeds this floor (sub-floor diffs are
    /// noise regardless of the ratio — a 0.1ms op doubling is not a
    /// regression signal).
    pub time_floor_ms: f64,
    /// `rate` (higher = better): fail when `new < base * (1 - rate_rel_tol)`.
    pub rate_rel_tol: f64,
    /// `stat`: fail when `|new - base| > stat_abs_tol`.
    pub stat_abs_tol: f64,
}

impl Default for Thresholds {
    fn default() -> Self {
        Thresholds {
            time_rel_tol: 0.35,
            time_floor_ms: 2.0,
            rate_rel_tol: 0.35,
            stat_abs_tol: 0.05,
        }
    }
}

/// Verdict for one metric.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Status {
    /// Within thresholds.
    Pass,
    /// Beyond the improvement threshold (reported, never fails).
    Improved,
    /// Beyond the regression threshold — fails the gate.
    Regressed,
    /// Present in the baseline, absent from the report — fails the gate
    /// (a silently dropped metric is how coverage rots).
    Missing,
    /// Present in the report only (new coverage; informational).
    New,
    /// Compared advisorily, never gating: a timing metric across different
    /// hosts, a stat metric across different configs, or a metric kind
    /// this binary does not know. The verdict line names the reason.
    Skipped,
}

impl Status {
    pub fn is_failure(&self) -> bool {
        matches!(self, Status::Regressed | Status::Missing)
    }

    pub fn label(&self) -> &'static str {
        match self {
            Status::Pass => "ok",
            Status::Improved => "improved",
            Status::Regressed => "REGRESSED",
            Status::Missing => "MISSING",
            Status::New => "new",
            Status::Skipped => "advisory",
        }
    }
}

/// One metric's comparison row.
#[derive(Clone, Debug)]
pub struct MetricDiff {
    pub case: String,
    pub name: String,
    pub kind: String,
    pub unit: String,
    pub base: Option<f64>,
    pub new: Option<f64>,
    pub status: Status,
}

impl MetricDiff {
    /// Relative change in percent (None when undefined).
    pub fn delta_pct(&self) -> Option<f64> {
        match (self.base, self.new) {
            (Some(b), Some(n)) if b != 0.0 => Some(100.0 * (n - b) / b),
            _ => None,
        }
    }
}

/// Full comparison of one (report, baseline) pair.
#[derive(Clone, Debug)]
pub struct CompareOutcome {
    pub bench: String,
    /// Same host fingerprint on both sides (timing gates active).
    pub host_match: bool,
    /// Same config fingerprint + full/quick mode on both sides.
    pub config_match: bool,
    pub diffs: Vec<MetricDiff>,
}

impl CompareOutcome {
    pub fn failures(&self) -> usize {
        self.diffs.iter().filter(|d| d.status.is_failure()).count()
    }

    pub fn passed(&self) -> bool {
        self.failures() == 0
    }

    fn rows(&self) -> Vec<[String; 6]> {
        self.diffs
            .iter()
            .map(|d| {
                let fmt = |v: Option<f64>| match v {
                    Some(x) if d.kind == kind::COUNT => format!("{x:.0}"),
                    Some(x) => format!("{x:.3}"),
                    None => "-".to_string(),
                };
                let delta = d
                    .delta_pct()
                    .map(|p| format!("{p:+.1}%"))
                    .unwrap_or_else(|| "-".to_string());
                [
                    format!("{}/{}", d.case, d.name),
                    d.kind.clone(),
                    fmt(d.base),
                    fmt(d.new),
                    delta,
                    d.status.label().to_string(),
                ]
            })
            .collect()
    }

    /// Fixed-width terminal table (one line per metric) + verdict line.
    pub fn table(&self) -> String {
        const HEADER: [&str; 6] = ["metric", "kind", "baseline", "new", "delta", "status"];
        let rows = self.rows();
        let mut widths: Vec<usize> = HEADER.iter().map(|h| h.len()).collect();
        for row in &rows {
            for (w, cell) in widths.iter_mut().zip(row.iter()) {
                *w = (*w).max(cell.len());
            }
        }
        let mut out = format!("bench {}: {}\n", self.bench, self.verdict());
        let line: String = HEADER
            .iter()
            .enumerate()
            .map(|(i, h)| format!("{:<w$}", h, w = widths[i] + 2))
            .collect();
        out.push_str(&line);
        out.push('\n');
        out.push_str(&"-".repeat(line.len()));
        out.push('\n');
        for row in &rows {
            for (i, cell) in row.iter().enumerate() {
                let _ = write!(out, "{:<w$}", cell, w = widths[i] + 2);
            }
            out.push('\n');
        }
        out
    }

    /// GitHub-flavored markdown table (for `$GITHUB_STEP_SUMMARY`).
    pub fn markdown(&self) -> String {
        let mut out = format!(
            "### bench `{}` — {}\n\n| metric | kind | baseline | new | delta | status |\n|---|---|---|---|---|---|\n",
            self.bench,
            self.verdict()
        );
        for row in self.rows() {
            let _ = writeln!(
                out,
                "| {} | {} | {} | {} | {} | {} |",
                row[0], row[1], row[2], row[3], row[4], row[5]
            );
        }
        out
    }

    /// One-line summary ("PASS (12 metrics, 1 improved, 3 advisory)" /
    /// "FAIL (2 regressions)").
    pub fn verdict(&self) -> String {
        let fails = self.failures();
        let count = |s: Status| self.diffs.iter().filter(|d| d.status == s).count();
        let mut notes = Vec::new();
        if !self.config_match {
            notes.push("config differs".to_string());
        }
        if !self.host_match {
            notes.push("host differs; timing advisory".to_string());
        }
        for (n, lbl) in [
            (count(Status::Improved), "improved"),
            (count(Status::New), "new"),
            (count(Status::Skipped), "advisory"),
        ] {
            if n > 0 {
                notes.push(format!("{n} {lbl}"));
            }
        }
        let notes = if notes.is_empty() {
            String::new()
        } else {
            format!(" ({})", notes.join(", "))
        };
        if fails == 0 {
            format!("PASS — {} metrics{notes}", self.diffs.len())
        } else {
            format!("FAIL — {fails} of {} metrics{notes}", self.diffs.len())
        }
    }
}

/// Judge one (baseline, new) pair of values under `th`.
///
/// `gate_timing` is false when the host fingerprints differ (wall-clock
/// numbers from different machines only inform); `gate_stats` is false
/// when the config fingerprint / quick-full mode / backend differ — stat
/// metrics are deterministic functions of the *configuration*, so a
/// cross-config comparison must not fail the gate. `count` metrics encode
/// structural contracts (manifest shapes, layer counts) and gate
/// everywhere.
fn judge(
    kind_tag: &str,
    base: f64,
    new: f64,
    th: &Thresholds,
    gate_timing: bool,
    gate_stats: bool,
) -> Status {
    match kind_tag {
        kind::COUNT => {
            if new == base {
                Status::Pass
            } else {
                Status::Regressed
            }
        }
        kind::STAT => {
            if !gate_stats {
                Status::Skipped
            } else if (new - base).abs() <= th.stat_abs_tol {
                Status::Pass
            } else {
                Status::Regressed
            }
        }
        kind::TIME_MS => {
            if !gate_timing {
                return Status::Skipped;
            }
            if new > base * (1.0 + th.time_rel_tol) && (new - base) > th.time_floor_ms {
                Status::Regressed
            } else if new < base * (1.0 - th.time_rel_tol) && (base - new) > th.time_floor_ms {
                Status::Improved
            } else {
                Status::Pass
            }
        }
        kind::RATE => {
            if !gate_timing {
                return Status::Skipped;
            }
            if new < base * (1.0 - th.rate_rel_tol) {
                Status::Regressed
            } else if new > base * (1.0 + th.rate_rel_tol) {
                Status::Improved
            } else {
                Status::Pass
            }
        }
        // Unknown kinds (a future format extension read by an old binary)
        // are advisory, never silently gating.
        _ => Status::Skipped,
    }
}

/// Diff `report` against `baseline`. `strict_host` forces timing gates even
/// across host fingerprints (the --strict-host flag).
pub fn compare(
    report: &BenchReport,
    baseline: &BenchReport,
    th: &Thresholds,
    strict_host: bool,
) -> CompareOutcome {
    let host_match = report.host.fingerprint() == baseline.host.fingerprint();
    let config_match = report.config_fingerprint == baseline.config_fingerprint
        && report.full_mode == baseline.full_mode
        && report.backend == baseline.backend;
    // Timing gates need the same machine (unless forced) AND the same
    // configuration — full-grid wall times against a quick-grid baseline
    // measure different workloads entirely.
    let gate_timing = (host_match || strict_host) && config_match;
    // Incomparable configurations (quick vs full grid, different
    // hyperparameters, different backend) downgrade config-dependent stat
    // metrics to advisory instead of reporting false regressions; timing
    // additionally requires the same host. A metric silently *disappearing*
    // still fails regardless — coverage rot is config-independent.
    let gate_stats = config_match;
    let mut diffs = Vec::new();

    // Every baseline metric must be judged (or flagged missing)...
    for case in &baseline.cases {
        for m in &case.metrics {
            let found = report.metric(&case.name, &m.name);
            let status = match found {
                Some(n) => judge(&m.kind, m.value, n.value, th, gate_timing, gate_stats),
                None => Status::Missing,
            };
            diffs.push(MetricDiff {
                case: case.name.clone(),
                name: m.name.clone(),
                kind: m.kind.clone(),
                unit: m.unit.clone(),
                base: Some(m.value),
                new: found.map(|n| n.value),
                status,
            });
        }
    }
    // ... and report-only metrics are surfaced as new coverage.
    for case in &report.cases {
        for m in &case.metrics {
            if baseline.metric(&case.name, &m.name).is_none() {
                diffs.push(MetricDiff {
                    case: case.name.clone(),
                    name: m.name.clone(),
                    kind: m.kind.clone(),
                    unit: m.unit.clone(),
                    base: None,
                    new: Some(m.value),
                    status: Status::New,
                });
            }
        }
    }
    CompareOutcome { bench: report.bench.clone(), host_match, config_match, diffs }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::report::{BenchCase, HostInfo, Metric, BENCH_FORMAT};

    fn metric(name: &str, value: f64, kind_tag: &str) -> Metric {
        Metric {
            name: name.into(),
            value,
            unit: "u".into(),
            kind: kind_tag.into(),
            repeats: 1,
        }
    }

    fn report(metrics: Vec<Metric>) -> BenchReport {
        BenchReport {
            format: BENCH_FORMAT,
            bench: "t".into(),
            tier: "smoke".into(),
            backend: "reference".into(),
            full_mode: false,
            config_fingerprint: "f".into(),
            host: HostInfo { os: "linux".into(), arch: "x86_64".into(), cpus: 4 },
            created_unix: 0,
            wall_secs: 0.0,
            cases: vec![BenchCase { name: "c".into(), metrics }],
        }
    }

    fn th() -> Thresholds {
        Thresholds::default()
    }

    #[test]
    fn identical_reports_pass() {
        let r = report(vec![
            metric("n", 384.0, kind::COUNT),
            metric("acc", 61.25, kind::STAT),
            metric("t", 10.0, kind::TIME_MS),
            metric("r", 100.0, kind::RATE),
        ]);
        let out = compare(&r, &r.clone(), &th(), false);
        assert!(out.passed(), "{}", out.table());
        assert!(out.host_match && out.config_match);
        assert_eq!(out.diffs.len(), 4);
        assert!(out.diffs.iter().all(|d| d.status == Status::Pass));
        assert!(out.verdict().starts_with("PASS"));
    }

    #[test]
    fn count_gates_exactly() {
        let base = report(vec![metric("n", 384.0, kind::COUNT)]);
        let ok = compare(&report(vec![metric("n", 384.0, kind::COUNT)]), &base, &th(), false);
        assert!(ok.passed());
        let bad = compare(&report(vec![metric("n", 385.0, kind::COUNT)]), &base, &th(), false);
        assert_eq!(bad.failures(), 1);
        assert_eq!(bad.diffs[0].status, Status::Regressed);
    }

    #[test]
    fn stat_tolerance_band_edges() {
        let base = report(vec![metric("acc", 60.0, kind::STAT)]);
        // Exactly at the band edge passes (<=), just beyond fails.
        let at_edge = report(vec![metric("acc", 60.0 + th().stat_abs_tol, kind::STAT)]);
        assert!(compare(&at_edge, &base, &th(), false).passed());
        let beyond = report(vec![metric("acc", 60.0 + th().stat_abs_tol * 1.01, kind::STAT)]);
        assert_eq!(compare(&beyond, &base, &th(), false).failures(), 1);
        // The band is symmetric: a drop fails too.
        let drop = report(vec![metric("acc", 59.0, kind::STAT)]);
        assert_eq!(compare(&drop, &base, &th(), false).failures(), 1);
    }

    #[test]
    fn missing_metric_fails_new_metric_does_not() {
        let base = report(vec![metric("a", 1.0, kind::COUNT), metric("b", 2.0, kind::COUNT)]);
        let new = report(vec![metric("a", 1.0, kind::COUNT), metric("c", 3.0, kind::COUNT)]);
        let out = compare(&new, &base, &th(), false);
        assert_eq!(out.failures(), 1, "{}", out.table());
        let b = out.diffs.iter().find(|d| d.name == "b").unwrap();
        assert_eq!(b.status, Status::Missing);
        assert_eq!(b.new, None);
        let c = out.diffs.iter().find(|d| d.name == "c").unwrap();
        assert_eq!(c.status, Status::New);
        assert!(!c.status.is_failure());
    }

    #[test]
    fn time_noise_floor_and_rel_tol_must_both_trip() {
        let t = th(); // rel 0.35, floor 2.0ms
        let base = report(vec![metric("op", 1.0, kind::TIME_MS)]);
        // 2.5x slower but only +1.5ms: under the noise floor -> pass.
        let small = report(vec![metric("op", 2.5, kind::TIME_MS)]);
        assert!(compare(&small, &base, &t, false).passed());
        // Large op: +30% is inside rel tol even though +30ms > floor.
        let base_big = report(vec![metric("op", 100.0, kind::TIME_MS)]);
        let within = report(vec![metric("op", 130.0, kind::TIME_MS)]);
        assert!(compare(&within, &base_big, &t, false).passed());
        // +50% and +50ms: both thresholds tripped -> regression.
        let slow = report(vec![metric("op", 150.0, kind::TIME_MS)]);
        let out = compare(&slow, &base_big, &t, false);
        assert_eq!(out.failures(), 1);
        // Symmetric improvement detection (never a failure).
        let fast = report(vec![metric("op", 50.0, kind::TIME_MS)]);
        let out = compare(&fast, &base_big, &t, false);
        assert!(out.passed());
        assert_eq!(out.diffs[0].status, Status::Improved);
    }

    #[test]
    fn rate_regression_direction() {
        let base = report(vec![metric("hps", 100.0, kind::RATE)]);
        // Lower throughput beyond tol fails; higher never does.
        let slow = report(vec![metric("hps", 60.0, kind::RATE)]);
        assert_eq!(compare(&slow, &base, &th(), false).failures(), 1);
        let fast = report(vec![metric("hps", 140.0, kind::RATE)]);
        let out = compare(&fast, &base, &th(), false);
        assert!(out.passed());
        assert_eq!(out.diffs[0].status, Status::Improved);
    }

    #[test]
    fn cross_host_timing_is_advisory_counts_still_gate() {
        let base = report(vec![
            metric("n", 384.0, kind::COUNT),
            metric("op", 1.0, kind::TIME_MS),
            metric("hps", 100.0, kind::RATE),
        ]);
        let mut new = report(vec![
            metric("n", 999.0, kind::COUNT),
            metric("op", 500.0, kind::TIME_MS),
            metric("hps", 1.0, kind::RATE),
        ]);
        new.host.cpus = 64; // different machine
        let out = compare(&new, &base, &th(), false);
        assert!(!out.host_match);
        // Only the count fails; both wall metrics are skipped.
        assert_eq!(out.failures(), 1);
        assert_eq!(
            out.diffs.iter().filter(|d| d.status == Status::Skipped).count(),
            2
        );
        // --strict-host turns them back into failures.
        let strict = compare(&new, &base, &th(), true);
        assert_eq!(strict.failures(), 3);
        assert!(strict.verdict().starts_with("FAIL"));
    }

    #[test]
    fn cross_config_stats_and_timing_are_advisory_counts_still_gate() {
        let base = report(vec![
            metric("n", 384.0, kind::COUNT),
            metric("acc", 60.0, kind::STAT),
            metric("op", 10.0, kind::TIME_MS),
        ]);
        // Same host, but the full/quick mode differs: the stat and the
        // timing are measurements of a different workload.
        let mut new = report(vec![
            metric("n", 384.0, kind::COUNT),
            metric("acc", 20.0, kind::STAT),
            metric("op", 500.0, kind::TIME_MS),
        ]);
        new.full_mode = true;
        let out = compare(&new, &base, &th(), false);
        assert!(!out.config_match);
        assert!(out.passed(), "{}", out.table());
        assert_eq!(
            out.diffs.iter().filter(|d| d.status == Status::Skipped).count(),
            2
        );
        // The structural count still gates across configs...
        new.cases[0].metrics[0].value = 999.0;
        assert_eq!(compare(&new, &base, &th(), false).failures(), 1);
        // ...and so does a missing metric (coverage rot is config-blind).
        new.cases[0].metrics.remove(1);
        new.cases[0].metrics[0].value = 384.0;
        let out = compare(&new, &base, &th(), false);
        assert_eq!(out.failures(), 1);
        assert!(out.diffs.iter().any(|d| d.status == Status::Missing));
    }

    #[test]
    fn unknown_kind_is_advisory() {
        let base = report(vec![metric("x", 1.0, "from_the_future")]);
        let new = report(vec![metric("x", 99.0, "from_the_future")]);
        let out = compare(&new, &base, &th(), false);
        assert!(out.passed());
        assert_eq!(out.diffs[0].status, Status::Skipped);
    }

    #[test]
    fn renders_table_and_markdown() {
        let base = report(vec![metric("n", 384.0, kind::COUNT)]);
        let new = report(vec![metric("n", 385.0, kind::COUNT)]);
        let out = compare(&new, &base, &th(), false);
        let table = out.table();
        assert!(table.contains("c/n") && table.contains("REGRESSED"), "{table}");
        let md = out.markdown();
        assert!(md.contains("| c/n |") && md.contains("FAIL"), "{md}");
        assert_eq!(out.diffs[0].delta_pct().map(|p| p.round()), Some(0.0));
    }
}
