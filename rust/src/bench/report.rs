//! `BENCH_<name>.json` — the machine-readable result of one benchmark run
//! (DESIGN.md §9).
//!
//! A [`BenchReport`] is the typed, serde-backed dual of what the benches
//! used to print ad hoc: per-case metric groups, each metric tagged with a
//! *kind* that tells the comparator how to judge a change, plus enough
//! provenance (config fingerprint, backend, host, quick/full mode, format
//! version) to know when two reports are even comparable. Reports are
//! written atomically (like `run.json`) and round-trip bit-identically
//! through [`crate::util::serde`] — `rust/tests/integration_bench.rs`
//! asserts it.
//!
//! Metric kinds and their comparison semantics (see
//! [`crate::bench::compare`]):
//!
//! | kind      | meaning                      | gate policy                |
//! |-----------|------------------------------|----------------------------|
//! | `count`   | exact integer contract       | any change fails           |
//! | `stat`    | deterministic float (acc, …) | absolute tolerance band    |
//! | `time_ms` | wall time, repeat-median     | rel. tol + noise floor;    |
//! |           |                              | advisory across hosts      |
//! | `rate`    | throughput (higher = better) | relative tolerance,        |
//! |           |                              | advisory across hosts      |

use crate::derive_serde;
use crate::runstore::write_atomic;
use crate::util::serde as sd;
use anyhow::{anyhow, bail, Context, Result};
use std::path::Path;

/// On-disk format version; [`BenchReport::load`] rejects anything else.
pub const BENCH_FORMAT: usize = 1;

/// Metric-kind tags (plain strings on disk; `derive_serde!` has no enums).
pub mod kind {
    /// Exact integer contract (manifest sizes, layer counts): any drift is
    /// a gate failure that must be re-blessed deliberately.
    pub const COUNT: &str = "count";
    /// Deterministic float (accuracies, losses): compared with an absolute
    /// tolerance band.
    pub const STAT: &str = "stat";
    /// Wall time in milliseconds (repeat-median): relative tolerance plus
    /// an absolute noise floor; advisory unless the hosts match.
    pub const TIME_MS: &str = "time_ms";
    /// Throughput, higher is better: relative tolerance; advisory unless
    /// the hosts match.
    pub const RATE: &str = "rate";
}

/// One measured value.
#[derive(Clone, Debug, PartialEq)]
pub struct Metric {
    pub name: String,
    pub value: f64,
    /// Display unit ("relus", "%", "ms", "hyp/s", ...).
    pub unit: String,
    /// One of the [`kind`] tags.
    pub kind: String,
    /// Samples folded into `value` (median); 1 for single observations.
    pub repeats: usize,
}
derive_serde!(Metric { name, value, unit, kind, repeats });

/// A named group of metrics (one scenario / model / budget point).
#[derive(Clone, Debug, PartialEq)]
pub struct BenchCase {
    pub name: String,
    pub metrics: Vec<Metric>,
}
derive_serde!(BenchCase { name, metrics });

/// Host provenance: enough to decide whether wall-clock comparisons
/// against a baseline mean anything.
#[derive(Clone, Debug, PartialEq)]
pub struct HostInfo {
    pub os: String,
    pub arch: String,
    pub cpus: usize,
}
derive_serde!(HostInfo { os, arch, cpus });

impl HostInfo {
    pub fn current() -> HostInfo {
        HostInfo {
            os: std::env::consts::OS.to_string(),
            arch: std::env::consts::ARCH.to_string(),
            cpus: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        }
    }

    /// Identity string for timing comparability ("linux/x86_64/8").
    pub fn fingerprint(&self) -> String {
        format!("{}/{}/{}", self.os, self.arch, self.cpus)
    }
}

/// The `BENCH_<name>.json` document.
#[derive(Clone, Debug, PartialEq)]
pub struct BenchReport {
    pub format: usize,
    /// Registry name ("smoke", "fig1", "perf", ...).
    pub bench: String,
    /// Registry tier ("smoke" | "paper" | "perf").
    pub tier: String,
    /// Backend that produced the numbers ("reference" | "pjrt").
    pub backend: String,
    /// True when CDNL_BENCH_FULL=1 selected the full paper grid. Full and
    /// quick reports measure different workloads: the comparator gates
    /// only structural `count` metrics across the mode boundary and
    /// downgrades everything else to advisory.
    pub full_mode: bool,
    /// Fingerprint of the canonical bench experiment configuration
    /// ([`crate::bench::setup::experiment`] on the default grid), so a
    /// hyperparameter change shows up as an identity change rather than a
    /// mysterious regression.
    pub config_fingerprint: String,
    pub host: HostInfo,
    pub created_unix: usize,
    /// Whole-benchmark wall time (provenance, never gated).
    pub wall_secs: f64,
    pub cases: Vec<BenchCase>,
}
derive_serde!(BenchReport {
    format,
    bench,
    tier,
    backend,
    full_mode,
    config_fingerprint,
    host,
    created_unix,
    wall_secs,
    cases,
});

impl BenchReport {
    /// Look up one metric by (case, name).
    pub fn metric(&self, case: &str, name: &str) -> Option<&Metric> {
        self.cases
            .iter()
            .find(|c| c.name == case)
            .and_then(|c| c.metrics.iter().find(|m| m.name == name))
    }

    /// Total metric count across cases.
    pub fn num_metrics(&self) -> usize {
        self.cases.iter().map(|c| c.metrics.len()).sum()
    }

    /// Atomically write `self` as pretty JSON.
    pub fn save(&self, path: &Path) -> Result<()> {
        write_atomic(path, sd::to_string_pretty(self).as_bytes())
            .with_context(|| format!("writing bench report {path:?}"))
    }

    /// Load + format-check a report.
    pub fn load(path: &Path) -> Result<BenchReport> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading {path:?}"))?;
        let r: BenchReport =
            sd::from_str(&text).map_err(|e| anyhow!("parsing {path:?}: {e}"))?;
        if r.format != BENCH_FORMAT {
            bail!(
                "{path:?}: bench report format {} unsupported (this build reads format {BENCH_FORMAT})",
                r.format
            );
        }
        Ok(r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> BenchReport {
        BenchReport {
            format: BENCH_FORMAT,
            bench: "smoke".into(),
            tier: "smoke".into(),
            backend: "reference".into(),
            full_mode: false,
            config_fingerprint: "0123456789abcdef".into(),
            host: HostInfo { os: "linux".into(), arch: "x86_64".into(), cpus: 8 },
            created_unix: 1_700_000_000,
            wall_secs: 1.25,
            cases: vec![BenchCase {
                name: "resnet_16x16_c10".into(),
                metrics: vec![
                    Metric {
                        name: "mask_size".into(),
                        value: 384.0,
                        unit: "relus".into(),
                        kind: kind::COUNT.into(),
                        repeats: 1,
                    },
                    Metric {
                        name: "eval_batch".into(),
                        value: 0.75,
                        unit: "ms".into(),
                        kind: kind::TIME_MS.into(),
                        repeats: 10,
                    },
                ],
            }],
        }
    }

    #[test]
    fn roundtrip_and_lookup() {
        let r = sample();
        let text = sd::to_string_pretty(&r);
        let back: BenchReport = sd::from_str(&text).unwrap();
        assert_eq!(back, r);
        // Serialization is canonical: a second pass is byte-identical.
        assert_eq!(sd::to_string_pretty(&back), text);
        assert_eq!(r.metric("resnet_16x16_c10", "mask_size").unwrap().value, 384.0);
        assert!(r.metric("resnet_16x16_c10", "nope").is_none());
        assert!(r.metric("nope", "mask_size").is_none());
        assert_eq!(r.num_metrics(), 2);
    }

    #[test]
    fn save_load_rejects_foreign_format() {
        let dir = std::env::temp_dir().join(format!("cdnl_bench_report_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("BENCH_smoke.json");
        let r = sample();
        r.save(&path).unwrap();
        assert_eq!(BenchReport::load(&path).unwrap(), r);
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, text.replace("\"format\": 1", "\"format\": 99")).unwrap();
        let err = format!("{:#}", BenchReport::load(&path).unwrap_err());
        assert!(err.contains("format 99"), "bad error: {err}");
    }

    #[test]
    fn host_fingerprint_shape() {
        let h = HostInfo::current();
        assert!(h.cpus >= 1);
        assert_eq!(h.fingerprint(), format!("{}/{}/{}", h.os, h.arch, h.cpus));
    }
}
