//! Shared benchmark setup, hoisted from the old `benches/common/mod.rs`
//! (every bench used to `#[path]`-include its own copy): experiment
//! presets, paper-budget scaling, the quick/full switch, and the shared
//! SNL-vs-Ours comparison harness.
//!
//! Every paper-tier benchmark regenerates one paper table/figure
//! (DESIGN.md §5). Budgets are the paper's, scaled by each backbone's
//! ReLU-count ratio (paper total / our total — Table 1 both sides).
//! `CDNL_BENCH_FULL=1` switches from the quick grid (a subset of budget
//! points, larger DRC so BCD runs ~8 iterations) to the full paper grid
//! with paper hyperparameters.
//!
//! All benches share the zoo cache under `results/zoo`, so trained
//! baselines and SNL reference models are built once across the suite.

use crate::config::Experiment;
use crate::runtime::Backend;
use std::path::{Path, PathBuf};

/// Paper Table 1 totals [#ReLUs] for scaling budgets to our backbones.
///
/// The ResNet-18 column covers the conv backbone (`resnet18`), its MLP
/// stand-in (`mlp`) and the stand-in's deprecated `resnet` name — all
/// three play the ResNet-18 role at a given image size; likewise the
/// WRN-22-8 column (README "bench-to-paper map").
pub fn paper_total(backbone: &str, image_size: usize) -> f64 {
    match (backbone, image_size) {
        ("resnet" | "mlp" | "resnet18", 16) => 570_000.0,
        ("resnet" | "mlp" | "resnet18", 32) => 1_966_000.0,
        ("wrn" | "mlpw" | "wrn22", 16) => 1_359_000.0,
        ("wrn" | "mlpw" | "wrn22", 32) => 5_439_000.0,
        _ => panic!("no paper total for {backbone}@{image_size}"),
    }
}

/// Scale a paper budget [#ReLUs] to our model, rounded to tens.
pub fn scale_budget(paper_budget: f64, our_total: usize, backbone: &str, image_size: usize) -> usize {
    let ratio = paper_total(backbone, image_size) / our_total as f64;
    ((paper_budget / ratio / 10.0).round() as usize) * 10
}

/// `CDNL_BENCH_FULL=1` selects the full paper grid.
pub fn full_mode() -> bool {
    std::env::var("CDNL_BENCH_FULL").map(|v| v == "1").unwrap_or(false)
}

/// Keep the first `quick_n` points of a budget grid unless in full mode.
pub fn grid<T: Clone>(points: &[T], quick_n: usize) -> Vec<T> {
    if full_mode() {
        points.to_vec()
    } else {
        points.iter().take(quick_n).cloned().collect()
    }
}

/// Experiment preset for benches: quick by default, paper-scale under
/// CDNL_BENCH_FULL=1. Out dir is `results/` so the zoo is shared.
pub fn experiment(dataset: &str, backbone: &str, poly: bool) -> Experiment {
    let mut exp = Experiment::default();
    let preset = if full_mode() { "full" } else { "quick" };
    for (k, v) in crate::config::preset(preset).unwrap() {
        exp.apply(&k, &v).unwrap();
    }
    exp.dataset = dataset.into();
    exp.backbone = backbone.into();
    exp.poly = poly;
    // 32x32 models are ~4x per step; in quick mode halve every schedule
    // (the paper itself drops TinyImageNet to 5 finetune epochs vs 20).
    if !full_mode() && dataset == "synthtiny" {
        exp.train.steps = 60;
        exp.snl.max_steps = 100;
        exp.snl.finetune_steps = 12;
        exp.bcd.finetune_steps = 8;
        exp.bcd.rt = 8;
    }
    exp
}

/// Tiny-but-real method schedules, shared by the smoke bench's
/// method-registry contract and the dispatch-parity integration test so
/// the two cannot drift apart: every method exercises its full control
/// flow in sub-second runs on the reference backend. `drc` is the
/// caller's BCD sweep size (the smoke contract uses 64 so one sweep lands
/// exactly on its gated budget; the parity test uses 32 for a multi-sweep
/// trajectory).
pub fn tiny_method_experiment(drc: usize) -> Experiment {
    let mut exp = Experiment::default();
    exp.snl.max_steps = 12;
    exp.snl.steps_per_check = 4;
    exp.snl.finetune_steps = 2;
    exp.bcd.drc = drc;
    exp.bcd.rt = 3;
    exp.bcd.finetune_steps = 2;
    exp.senet.proxy_batches = 1;
    exp.senet.layer_trials = 2;
    exp.senet.kd_steps = 2;
    exp.deepreduce.proxy_batches = 1;
    exp.deepreduce.finetune_steps = 2;
    exp
}

/// The BCD reference budget for a target: paper rule in full mode
/// (config::reference_budget); in quick mode `target + 8*DRC` so every BCD
/// run costs ~8 iterations and the zoo cache is shared across benches.
pub fn bref_for(exp: &Experiment, total: usize, target: usize) -> usize {
    if full_mode() {
        crate::config::reference_budget(total, target)
    } else {
        (target + 8 * exp.bcd.drc).min(total)
    }
}

/// One (budget, SNL accuracy, BCD-ours accuracy) comparison point — the
/// row shape of Tables 2/3 and the curves of Fig. 1.
#[derive(Clone, Debug)]
pub struct PointResult {
    pub dataset: String,
    pub budget: usize,
    pub bref: usize,
    pub snl_acc: f64,
    pub ours_acc: f64,
}

/// Run the paper's core comparison on one dataset: SNL trained directly to
/// each target vs BCD ("ours") run from the SNL reference at B_ref.
/// All stages go through the shared zoo cache.
pub fn snl_vs_ours(
    engine: &dyn Backend,
    dataset: &str,
    backbone: &str,
    budgets: &[usize],
) -> anyhow::Result<Vec<PointResult>> {
    if budgets.is_empty() {
        // Quick-mode grids legitimately empty out (table2 skips synthtiny);
        // don't pay session + dataset construction for zero points.
        return Ok(Vec::new());
    }
    let exp = experiment(dataset, backbone, false);
    let pl = crate::pipeline::Pipeline::new(engine, exp)?;
    let total = pl.sess.info().total_relus();
    let mut out = Vec::new();
    for &budget in budgets {
        let bref = bref_for(&pl.exp, total, budget);
        println!("[{dataset}/{backbone}] budget {budget} (B_ref {bref}) ...");
        let snl_direct = pl.snl_ref(budget)?; // SNL straight to the target
        let snl_acc = pl.test_acc(&snl_direct)?;
        let reference = pl.snl_ref(bref)?;
        let ours = pl.bcd_cached(&reference, budget)?;
        let ours_acc = pl.test_acc(&ours)?;
        println!("[{dataset}/{backbone}] budget {budget}: SNL {snl_acc:.2}%  Ours {ours_acc:.2}%");
        out.push(PointResult {
            dataset: dataset.to_string(),
            budget,
            bref,
            snl_acc,
            ours_acc,
        });
    }
    Ok(out)
}

/// Print + persist a Table 2/3-style block and report the shape criterion
/// (Ours >= SNL on most budgets, gap widening at low budgets).
pub fn report_snl_vs_ours(id: &str, title: &str, points: &[PointResult]) -> anyhow::Result<()> {
    let rows: Vec<Vec<String>> = points
        .iter()
        .map(|p| {
            vec![
                p.dataset.clone(),
                crate::util::fmt_relu_count(p.budget),
                format!("{:.2}", p.snl_acc),
                format!("{:.2}", p.ours_acc),
                format!("{:+.2}", p.ours_acc - p.snl_acc),
            ]
        })
        .collect();
    crate::metrics::print_table(title, &["dataset", "budget", "SNL", "Ours", "gap"], &rows);
    crate::metrics::write_csv(
        &results_csv(id),
        &["dataset", "budget", "bref", "snl_acc", "ours_acc"],
        &points
            .iter()
            .map(|p| {
                vec![
                    p.dataset.clone(),
                    p.budget.to_string(),
                    p.bref.to_string(),
                    format!("{:.3}", p.snl_acc),
                    format!("{:.3}", p.ours_acc),
                ]
            })
            .collect::<Vec<_>>(),
    )?;
    let wins = points.iter().filter(|p| p.ours_acc >= p.snl_acc).count();
    println!(
        "\nshape criterion: Ours >= SNL on {wins}/{} budgets (paper: every budget)",
        points.len()
    );
    Ok(())
}

/// The bench backend: PJRT over `artifacts/` when available (and compiled
/// in), otherwise the pure-Rust reference backend.
pub fn engine() -> Box<dyn Backend> {
    crate::util::logging::init();
    let be = crate::runtime::open_backend(Path::new("artifacts"), "auto").expect("backend");
    println!("backend: {}", be.name());
    be
}

/// `results/<id>.csv` — the CSV every paper bench persists next to its
/// terminal table.
pub fn results_csv(id: &str) -> PathBuf {
    PathBuf::from("results").join(format!("{id}.csv"))
}

/// Standard bench banner.
pub fn banner(id: &str, what: &str) {
    println!("=== {id}: {what} ===");
    println!(
        "mode: {} (set CDNL_BENCH_FULL=1 for the full paper grid)",
        if full_mode() { "FULL" } else { "quick" }
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn budget_scaling_rounds_to_tens() {
        // 570K paper total, 384 ours => ratio ~1484; 50K scales to ~30.
        let b = scale_budget(50e3, 384, "resnet", 16);
        assert_eq!(b % 10, 0);
        assert!(b > 0);
    }

    #[test]
    fn grid_respects_quick_n() {
        // full_mode() is env-driven; quick is the default in tests.
        let g = grid(&[1, 2, 3, 4], 2);
        assert!(g == vec![1, 2] || g.len() == 4); // env may force full
    }

    #[test]
    fn bref_quick_rule_caps_at_total() {
        let exp = experiment("synth10", "resnet", false);
        assert!(bref_for(&exp, 384, 380) <= 384);
    }
}
