//! The unified benchmark subsystem (DESIGN.md §9).
//!
//! Every benchmark — the 15 paper tables/figures plus the perf and smoke
//! suites — is a registered [`BenchDef`]: a name, a [`Tier`], and a run
//! function over a [`BenchCtx`]. One driver ([`run_bench`]) owns the
//! lifecycle all benches used to hand-roll: banner, backend, timing, and a
//! typed [`report::BenchReport`] written to `BENCH_<name>.json`. The old
//! `benches/bench_*.rs` binaries survive as thin wrappers over
//! [`bench_main`], and the CLI drives the same registry via
//! `cdnl bench list|run|compare` (`main.rs`).
//!
//! Tiers:
//! - `smoke` — seconds; structural counts + hot-path micro timings; runs in
//!   CI on every push and gates against the committed baseline;
//! - `paper` — the table/figure grid (minutes in quick mode, hours under
//!   `CDNL_BENCH_FULL=1`);
//! - `perf`  — the §Perf microbenchmark suite;
//! - `serve` — the fleet-scale PI serving simulation ([`crate::pi::serve`]):
//!   percentile latency + throughput vs ReLU budget, count metrics gated.
//!
//! Reports land in `results/bench/BENCH_<name>.json`; committed baselines
//! live at the repository root (`BENCH_<name>.json`), and
//! `cdnl bench compare --gate` diffs the two ([`compare`]).

pub mod compare;
pub mod report;
pub mod setup;
pub mod suite;

pub use compare::{compare as compare_reports, CompareOutcome, Status, Thresholds};
pub use report::{BenchCase, BenchReport, HostInfo, Metric, BENCH_FORMAT};

use crate::runtime::Backend;
use anyhow::{anyhow, Result};
use std::path::{Path, PathBuf};

/// Benchmark tier: how expensive it is and where it runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tier {
    Smoke,
    Paper,
    Perf,
    Serve,
}

impl Tier {
    pub fn parse(s: &str) -> Option<Tier> {
        match s {
            "smoke" => Some(Tier::Smoke),
            "paper" => Some(Tier::Paper),
            "perf" => Some(Tier::Perf),
            "serve" => Some(Tier::Serve),
            _ => None,
        }
    }

    /// Canonical name, the inverse of [`Self::parse`].
    pub fn name(&self) -> &'static str {
        match self {
            Tier::Smoke => "smoke",
            Tier::Paper => "paper",
            Tier::Perf => "perf",
            Tier::Serve => "serve",
        }
    }
}

/// One registered benchmark.
pub struct BenchDef {
    /// Registry name; the report file is `BENCH_<name>.json`.
    pub name: &'static str,
    pub tier: Tier,
    /// One-line description (the old per-bench banner text).
    pub title: &'static str,
    /// Paper artifact this bench regenerates ("Table 2", "Fig. 7", "-").
    pub paper: &'static str,
    pub run: fn(&mut BenchCtx) -> Result<()>,
}

/// Execution context handed to every suite function: the backend, the
/// quick/full switch, and the metric sink the driver turns into a
/// [`BenchReport`].
pub struct BenchCtx<'e> {
    pub engine: &'e dyn Backend,
    /// `CDNL_BENCH_FULL=1` — suites use this instead of re-reading the env.
    pub full: bool,
    cases: Vec<BenchCase>,
}

impl<'e> BenchCtx<'e> {
    pub fn new(engine: &'e dyn Backend) -> BenchCtx<'e> {
        BenchCtx { engine, full: setup::full_mode(), cases: Vec::new() }
    }

    fn push(&mut self, case: &str, m: Metric) {
        match self.cases.iter_mut().find(|c| c.name == case) {
            Some(c) => c.metrics.push(m),
            None => self.cases.push(BenchCase { name: case.to_string(), metrics: vec![m] }),
        }
    }

    /// Record an exact-integer metric (gate: any change fails).
    pub fn count(&mut self, case: &str, name: &str, value: usize, unit: &str) {
        self.push(
            case,
            Metric {
                name: name.to_string(),
                value: value as f64,
                unit: unit.to_string(),
                kind: report::kind::COUNT.to_string(),
                repeats: 1,
            },
        );
    }

    /// Record a deterministic float metric (gate: absolute tolerance band).
    pub fn stat(&mut self, case: &str, name: &str, value: f64, unit: &str) {
        self.push(
            case,
            Metric {
                name: name.to_string(),
                value,
                unit: unit.to_string(),
                kind: report::kind::STAT.to_string(),
                repeats: 1,
            },
        );
    }

    /// Record a wall-time metric as the repeat-median of `samples_ms`.
    pub fn time_ms(&mut self, case: &str, name: &str, samples_ms: &[f64]) {
        self.push(
            case,
            Metric {
                name: name.to_string(),
                value: crate::util::percentile(samples_ms, 50.0),
                unit: "ms".to_string(),
                kind: report::kind::TIME_MS.to_string(),
                repeats: samples_ms.len().max(1),
            },
        );
    }

    /// Record a throughput metric (higher is better).
    pub fn rate(&mut self, case: &str, name: &str, value: f64, unit: &str) {
        self.push(
            case,
            Metric {
                name: name.to_string(),
                value,
                unit: unit.to_string(),
                kind: report::kind::RATE.to_string(),
                repeats: 1,
            },
        );
    }
}

/// The benchmark registry — the single source of truth `cdnl bench list`,
/// the thin `benches/*.rs` wrappers and CI all share.
pub fn registry() -> &'static [BenchDef] {
    &REGISTRY
}

static REGISTRY: [BenchDef; 19] = [
    BenchDef {
        name: "smoke",
        tier: Tier::Smoke,
        title: "structural manifest contract + hot-path micro timings",
        paper: "-",
        run: suite::smoke::run,
    },
    BenchDef {
        name: "table1",
        tier: Tier::Paper,
        title: "Overall number of ReLUs per network x image size",
        paper: "Table 1",
        run: suite::table1::run,
    },
    BenchDef {
        name: "table2",
        tier: Tier::Paper,
        title: "WideResNet-22-8: SNL vs Ours across budgets",
        paper: "Table 2",
        run: suite::table2::run,
    },
    BenchDef {
        name: "table3",
        tier: Tier::Paper,
        title: "ResNet18: SNL vs Ours across budgets",
        paper: "Table 3",
        run: suite::table3::run,
    },
    BenchDef {
        name: "fig1",
        tier: Tier::Paper,
        title: "Accuracy vs ReLU budget, ResNet18, 3 datasets, 4 methods",
        paper: "Fig. 1",
        run: suite::fig1::run,
    },
    BenchDef {
        name: "fig3",
        tier: Tier::Paper,
        title: "Ours vs SENet, relative-to-baseline accuracy",
        paper: "Fig. 3",
        run: suite::fig3::run,
    },
    BenchDef {
        name: "fig4",
        tier: Tier::Paper,
        title: "Ours on top of AutoReP, synth100, poly backbones",
        paper: "Fig. 4",
        run: suite::fig4::run,
    },
    BenchDef {
        name: "fig5",
        tier: Tier::Paper,
        title: "BCD hyperparameter ablations (DRC / finetune / ADT)",
        paper: "Fig. 5",
        run: suite::fig5::run,
    },
    BenchDef {
        name: "fig6",
        tier: Tier::Paper,
        title: "SNL mask IoU dynamics",
        paper: "Fig. 6",
        run: suite::fig6::run,
    },
    BenchDef {
        name: "fig7",
        tier: Tier::Paper,
        title: "ReLU distribution across layers",
        paper: "Fig. 7",
        run: suite::fig7::run,
    },
    BenchDef {
        name: "fig8",
        tier: Tier::Paper,
        title: "Ours vs SENet on the wide backbone (Fig. 3 harness)",
        paper: "Fig. 8 (supp)",
        run: suite::fig8::run,
    },
    BenchDef {
        name: "fig9",
        tier: Tier::Paper,
        title: "SNL accuracy vs kappa; BCD overlay",
        paper: "Fig. 9 (supp)",
        run: suite::fig9::run,
    },
    BenchDef {
        name: "fig10",
        tier: Tier::Paper,
        title: "SNL budget vs step + decrease-rate trace",
        paper: "Fig. 10 (supp)",
        run: suite::fig10::run,
    },
    BenchDef {
        name: "fig11",
        tier: Tier::Paper,
        title: "SNL alpha trajectories vs lambda schedule",
        paper: "Fig. 11 (supp)",
        run: suite::fig11::run,
    },
    BenchDef {
        name: "ablations",
        tier: Tier::Paper,
        title: "DRC schedule / granularity / hysteresis ablations",
        paper: "beyond paper",
        run: suite::ablations::run,
    },
    BenchDef {
        name: "perf",
        tier: Tier::Perf,
        title: "L3 hot-path microbenchmarks",
        paper: "§Perf",
        run: suite::perf::run,
    },
    BenchDef {
        name: "perf_conv_lowered",
        tier: Tier::Perf,
        title: "GEMM-lowered conv: direct vs lowered vs slab-reused scans",
        paper: "§Perf",
        run: suite::perf_conv_lowered::run,
    },
    BenchDef {
        name: "perf_dist",
        tier: Tier::Perf,
        title: "distributed scan: 1 vs N loopback workers, outcome-checked",
        paper: "§Perf",
        run: suite::perf_dist::run,
    },
    BenchDef {
        name: "serve",
        tier: Tier::Serve,
        title: "fleet-scale PI serving: percentiles + throughput vs budget",
        paper: "-",
        run: suite::serve::run,
    },
];

/// Look up one benchmark by registry name.
pub fn find(name: &str) -> Result<&'static BenchDef> {
    registry()
        .iter()
        .find(|d| d.name == name)
        .ok_or_else(|| anyhow!("no benchmark {name:?} (try `cdnl bench list`)"))
}

/// All benchmarks of one tier, registry order.
pub fn by_tier(tier: Tier) -> Vec<&'static BenchDef> {
    registry().iter().filter(|d| d.tier == tier).collect()
}

/// Default location a fresh report is written to.
pub fn default_report_dir() -> PathBuf {
    PathBuf::from("results").join("bench")
}

/// `<dir>/BENCH_<name>.json`.
pub fn report_path(dir: &Path, name: &str) -> PathBuf {
    dir.join(format!("BENCH_{name}.json"))
}

/// Run one benchmark on `engine` and build its typed report. The driver
/// owns the banner, the wall clock, and the provenance fields; the suite
/// function only measures and records.
pub fn run_bench(def: &BenchDef, engine: &dyn Backend) -> Result<BenchReport> {
    setup::banner(def.name, def.title);
    let t0 = std::time::Instant::now();
    let mut cx = BenchCtx::new(engine);
    (def.run)(&mut cx)?;
    Ok(BenchReport {
        format: BENCH_FORMAT,
        bench: def.name.to_string(),
        tier: def.tier.name().to_string(),
        backend: engine.name().to_string(),
        full_mode: cx.full,
        // Identity of the canonical bench-grid configuration: hyperparameter
        // changes move this fingerprint, flagging reports as incomparable
        // instead of mysteriously regressed.
        config_fingerprint: setup::experiment("synth10", "resnet", false).fingerprint(),
        host: HostInfo::current(),
        created_unix: crate::runstore::manifest::now_unix(),
        wall_secs: t0.elapsed().as_secs_f64(),
        cases: cx.cases,
    })
}

/// Run one benchmark, persist its report under `report_dir`, and print the
/// one-line summary — the shared tail of [`bench_main`] and the CLI's
/// `cdnl bench run` (main.rs), so the two entry points cannot drift.
pub fn run_and_save(
    def: &BenchDef,
    engine: &dyn Backend,
    report_dir: &Path,
) -> Result<BenchReport> {
    let report = run_bench(def, engine)?;
    let path = report_path(report_dir, def.name);
    report.save(&path)?;
    println!(
        "\nreport: {} ({} cases, {} metrics, {:.1}s) -> {}",
        report.bench,
        report.cases.len(),
        report.num_metrics(),
        report.wall_secs,
        path.display()
    );
    Ok(report)
}

/// Entry point for the thin `benches/bench_<name>.rs` wrappers (`cargo
/// bench --bench bench_<name>`): open the auto backend, run, persist the
/// report to [`default_report_dir`].
pub fn bench_main(name: &str) -> Result<()> {
    let def = find(name)?;
    let engine = setup::engine();
    run_and_save(def, engine.as_ref(), &default_report_dir())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_names_unique_and_findable() {
        let mut seen = std::collections::HashSet::new();
        for d in registry() {
            assert!(seen.insert(d.name), "duplicate bench name {}", d.name);
            assert!(find(d.name).is_ok());
            assert!(!d.title.is_empty() && !d.paper.is_empty());
        }
        assert!(find("nope").is_err());
        assert_eq!(registry().len(), 19);
    }

    #[test]
    fn tiers_parse_and_partition() {
        for t in [Tier::Smoke, Tier::Paper, Tier::Perf, Tier::Serve] {
            assert_eq!(Tier::parse(t.name()), Some(t));
        }
        assert_eq!(Tier::parse("bogus"), None);
        assert_eq!(by_tier(Tier::Smoke).len(), 1);
        assert_eq!(by_tier(Tier::Perf).len(), 3);
        assert_eq!(by_tier(Tier::Serve).len(), 1);
        assert_eq!(
            by_tier(Tier::Paper).len() + 5,
            registry().len(),
            "every bench belongs to exactly one tier"
        );
    }

    #[test]
    fn ctx_records_metric_kinds() {
        let be = crate::runtime::RefBackend::standard();
        let mut cx = BenchCtx::new(&be);
        cx.count("c", "n", 384, "relus");
        cx.stat("c", "acc", 61.5, "%");
        cx.time_ms("c", "op", &[3.0, 1.0, 2.0]);
        cx.rate("c2", "hps", 100.0, "hyp/s");
        assert_eq!(cx.cases.len(), 2);
        let m = &cx.cases[0].metrics;
        assert_eq!(m.len(), 3);
        assert_eq!(m[2].value, 2.0, "time_ms must record the median");
        assert_eq!(m[2].repeats, 3);
        assert_eq!(m[0].kind, report::kind::COUNT);
        assert_eq!(m[1].kind, report::kind::STAT);
        assert_eq!(cx.cases[1].metrics[0].kind, report::kind::RATE);
    }

    #[test]
    fn report_paths() {
        assert_eq!(
            report_path(Path::new("x"), "smoke"),
            PathBuf::from("x").join("BENCH_smoke.json")
        );
    }
}
