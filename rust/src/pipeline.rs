//! Experiment pipeline: the composition layer every bench, example and CLI
//! subcommand shares.
//!
//! A [`Pipeline`] owns one (backend session, dataset pair, experiment
//! config) triple and produces the staged models of the paper's protocol:
//!
//! ```text
//! baseline (full ReLUs, trained)        -> pipeline.baseline()
//!   └─ SNL reference at B_ref           -> pipeline.snl_ref(b_ref)
//!        └─ BCD down to B_target        -> pipeline.bcd_from(&ref, b_target)
//!   └─ AutoReP reference at B_ref (poly)-> pipeline.autorep_ref(b_ref)
//! ```
//!
//! Expensive stages are cached in the model zoo keyed by (model, dataset,
//! stage, budget, seed) so figure benches that share prefixes don't retrain.
//! Every zoo access is recorded as a [`StageRecord`] so runs created
//! through [`Pipeline::bcd_record`] carry their full staging provenance in
//! `run.json` — and [`Pipeline::bcd_resume`] continues an interrupted run
//! bit-identically (see [`crate::runstore`]).

use crate::config::Experiment;
use crate::coordinator::bcd::{
    local_scanner, run_bcd, run_bcd_resumable_with, BcdOutcome, IterRecord, TrialScanner,
};
use crate::coordinator::eval::test_accuracy;
use crate::coordinator::train::train;
use crate::data::{synth, Dataset};
use crate::methods::registry::{self, ChainSpec, Method, MethodCtx, MethodOutcome, RecordSink};
use crate::model::{zoo, ModelState};
use crate::runstore::{
    BcdRecorder, RunDir, RunManifest, RunStateError, RunStore, StageRecord, COMPLETE, FAILED,
    RUNNING,
};
use crate::runtime::backend::Backend;
use crate::runtime::session::Session;
use anyhow::{anyhow, bail, Context, Result};
use std::path::PathBuf;

/// One experiment's shared context.
pub struct Pipeline<'e> {
    pub sess: Session<'e>,
    pub exp: Experiment,
    pub train_ds: Dataset,
    pub test_ds: Dataset,
    zoo_dir: PathBuf,
    /// Zoo accesses and chain stages since the last [`Self::take_stages`]
    /// (run provenance; also the [`MethodCtx`] record sink).
    stages: RecordSink,
}

impl<'e> Pipeline<'e> {
    pub fn new(backend: &'e dyn Backend, exp: Experiment) -> Result<Pipeline<'e>> {
        let sess = Session::new(backend, &exp.model_key())
            .with_context(|| format!("experiment wants model {}", exp.model_key()))?;
        let spec = synth::by_name(&exp.dataset)
            .ok_or_else(|| anyhow!("unknown dataset {:?}", exp.dataset))?;
        let (train_ds, test_ds) = synth::generate(spec);
        // Namespace the zoo by backend: checkpoints from different backends
        // share model keys but not numerics, and must never cross-pollinate.
        let zoo_dir = PathBuf::from(&exp.out_dir).join("zoo").join(backend.name());
        Ok(Pipeline {
            sess,
            exp,
            train_ds,
            test_ds,
            zoo_dir,
            stages: RecordSink::default(),
        })
    }

    /// The [`MethodCtx`] this pipeline hands to registry methods: its
    /// session, training split, config and stage-provenance sink.
    pub fn ctx(&self) -> MethodCtx<'_> {
        MethodCtx::new(&self.sess, &self.train_ds, &self.exp, &self.stages)
    }

    /// Zoo access with provenance recording.
    fn staged<F>(&self, stage: &str, tag: &str, build: F) -> Result<ModelState>
    where
        F: FnOnce() -> Result<ModelState>,
    {
        let (st, info) = zoo::cached_traced(&self.zoo_dir, self.sess.info(), tag, build)?;
        self.stages.lock().unwrap().push(StageRecord {
            stage: stage.to_string(),
            path: info.path.display().to_string(),
            budget: st.budget(),
            cached: info.hit,
            wall_secs: info.wall_secs,
        });
        Ok(st)
    }

    /// Drain the stage-provenance log (recorded into a run manifest).
    /// Nested builds log their prerequisites too, so the order is
    /// dependency-first; duplicates from repeated zoo hits are collapsed.
    pub fn take_stages(&self) -> Vec<StageRecord> {
        let mut v: Vec<StageRecord> = std::mem::take(&mut *self.stages.lock().unwrap());
        let mut seen = std::collections::HashSet::new();
        v.retain(|s| seen.insert((s.stage.clone(), s.path.clone())));
        v
    }

    /// Trained full-ReLU baseline (cached).
    pub fn baseline(&self) -> Result<ModelState> {
        let tag = format!(
            "{}_base_s{}_t{}",
            self.exp.dataset, self.exp.train.seed, self.exp.train.steps
        );
        self.staged("baseline", &tag, || {
            let mut st = self.sess.init_state(self.exp.train.seed as i32)?;
            train(&self.sess, &mut st, &self.train_ds, &self.exp.train)?;
            Ok(st)
        })
    }

    /// SNL reference model at `b_ref` ReLUs, from the baseline (cached).
    /// This is the model BCD starts from — paper Tables 4/5. Runs through
    /// the method registry, so it is exactly `cdnl run snl` numerics.
    pub fn snl_ref(&self, b_ref: usize) -> Result<ModelState> {
        if b_ref >= self.sess.info().total_relus() {
            return self.baseline(); // degenerate: reference == full network
        }
        let tag = format!(
            "{}_snlref_b{}_s{}",
            self.exp.dataset, b_ref, self.exp.snl.seed
        );
        self.staged("snl_ref", &tag, || {
            let mut st = self.baseline()?;
            registry::find("snl")?.run(&self.ctx(), &mut st, b_ref)?;
            Ok(st)
        })
    }

    /// AutoReP reference model at `b_ref` ReLUs (poly variants; cached).
    /// Registry-dispatched, like [`Self::snl_ref`].
    pub fn autorep_ref(&self, b_ref: usize) -> Result<ModelState> {
        if b_ref >= self.sess.info().total_relus() {
            return self.baseline();
        }
        let tag = format!(
            "{}_arpref_b{}_s{}",
            self.exp.dataset, b_ref, self.exp.snl.seed
        );
        self.staged("autorep_ref", &tag, || {
            let mut st = self.baseline()?;
            registry::find("autorep")?.run(&self.ctx(), &mut st, b_ref)?;
            Ok(st)
        })
    }

    /// Execute a parsed method chain from the baseline (or from `from`
    /// when given): stage `i` reduces to `budgets[i]`. The generalization
    /// of the paper's staging protocol — `snl+bcd` at `(B_ref, B_target)`
    /// is exactly [`Self::snl_ref`] followed by [`Self::bcd_from`]
    /// (asserted bit-identical in `rust/tests/integration_registry.rs`).
    /// Per-stage provenance lands in the stage sink for the run manifest.
    pub fn run_chain(
        &self,
        spec: &ChainSpec,
        from: Option<ModelState>,
        budgets: &[usize],
    ) -> Result<(ModelState, Vec<MethodOutcome>)> {
        let mut st = match from {
            Some(st) => st,
            None => self.baseline()?,
        };
        let outs = spec.run(&self.ctx(), &mut st, budgets)?;
        Ok((st, outs))
    }

    /// Run BCD from a copy of `reference` down to `b_target`; returns the
    /// reduced state and the iteration trace.
    pub fn bcd_from(
        &self,
        reference: &ModelState,
        b_target: usize,
    ) -> Result<(ModelState, BcdOutcome)> {
        let mut st = reference.clone();
        let out = run_bcd(&self.sess, &mut st, &self.train_ds, b_target, &self.exp.bcd, 0)?;
        Ok((st, out))
    }

    /// Zoo-cached BCD: like [`Self::bcd_from`] but keyed on the run's
    /// determinants (dataset, reference budget, target, BCD knobs, seed) so
    /// benches sharing a configuration don't recompute. The iteration trace
    /// is not cached — use `bcd_from` when you need it.
    pub fn bcd_cached(&self, reference: &ModelState, b_target: usize) -> Result<ModelState> {
        let b = &self.exp.bcd;
        // Non-default schedule/granularity are tagged explicitly; the paper
        // configuration keeps the plain tag (stable across releases).
        let variant = if b.drc_schedule == crate::config::DrcSchedule::Constant
            && b.granularity == crate::config::Granularity::Pixel
        {
            String::new()
        } else {
            format!("_{:?}{:?}", b.drc_schedule, b.granularity)
        };
        let tag = format!(
            "{}_bcd_r{}_t{}_d{}{}_rt{}_a{}_f{}_s{}",
            self.exp.dataset,
            reference.budget(),
            b_target,
            b.drc,
            variant,
            b.rt,
            b.adt,
            b.finetune_steps,
            b.seed
        );
        self.staged("bcd", &tag, || Ok(self.bcd_from(reference, b_target)?.0))
    }

    // ---- run-store orchestration ------------------------------------------

    /// Run BCD on `st` with sweep-by-sweep durability: creates a run
    /// directory in `store` (manifest + reference checkpoint + staging
    /// provenance), persists every completed sweep through a
    /// [`BcdRecorder`], and marks the run `complete`/`failed`. If the
    /// process dies mid-run, `cdnl runs resume <id>` picks up from the last
    /// completed sweep.
    pub fn bcd_record(
        &self,
        store: &RunStore,
        st: &mut ModelState,
        b_target: usize,
    ) -> Result<(BcdOutcome, RunDir)> {
        let mut scan = local_scanner(&self.exp.bcd);
        self.bcd_record_with(store, st, b_target, &mut scan)
    }

    /// [`Self::bcd_record`] with a caller-supplied trial scanner — how the
    /// distributed scan ([`crate::dist::dist_scanner`]) gets the same
    /// sweep-by-sweep durability, `run.json` cursors and resume semantics
    /// as a local run.
    pub fn bcd_record_with(
        &self,
        store: &RunStore,
        st: &mut ModelState,
        b_target: usize,
        scan: &mut TrialScanner,
    ) -> Result<(BcdOutcome, RunDir)> {
        let backend = self.sess.backend.name();
        let mut m = RunManifest::new("bcd", &self.exp, backend, st.budget(), b_target);
        m.stages = self.take_stages();
        let mut run = store.create(m)?;
        crate::runstore::save_state_atomic(st, &run.ref_state_path())?;
        crate::info!("runstore: recording run {} in {:?}", run.manifest.run_id, run.dir);

        let result = {
            let mut rec = BcdRecorder::new(&mut run);
            run_bcd_resumable_with(
                &self.sess,
                st,
                &self.train_ds,
                b_target,
                &self.exp.bcd,
                0,
                None,
                &mut |ev| rec.observe(ev),
                scan,
            )
        };
        self.seal(run, result)
    }

    /// Continue an interrupted run from its last durable sweep. Returns the
    /// final state plus the *stitched* outcome: recorded sweeps from before
    /// the interruption followed by the sweeps executed now — field-for-
    /// field what the uninterrupted run would have produced (timings aside).
    pub fn bcd_resume(&self, run: RunDir) -> Result<(ModelState, BcdOutcome, RunDir)> {
        let mut scan = local_scanner(&self.exp.bcd);
        self.bcd_resume_with(run, &mut scan)
    }

    /// [`Self::bcd_resume`] with a caller-supplied trial scanner (the
    /// distributed-scan entry point): a `cdnl coordinate` run interrupted
    /// mid-descent resumes from its `run.json` cursor exactly like a local
    /// one, whatever scanner finishes it.
    pub fn bcd_resume_with(
        &self,
        mut run: RunDir,
        scan: &mut TrialScanner,
    ) -> Result<(ModelState, BcdOutcome, RunDir)> {
        let m = &run.manifest;
        if m.status == COMPLETE {
            return Err(RunStateError::AlreadyComplete { run_id: m.run_id.clone() }.into());
        }
        if m.method != "bcd" {
            bail!("run {} is a {:?} run; only bcd runs resume", m.run_id, m.method);
        }
        if m.model_key != self.sess.key {
            bail!(
                "run {} is for model {:?}, this pipeline drives {:?}",
                m.run_id,
                m.model_key,
                self.sess.key
            );
        }
        let b_target = m.b_target;
        let mut st = run.load_resume_state(self.sess.info())?;
        let cursor = match &run.manifest.bcd {
            Some(p) if p.sweeps_done > 0 => Some(p.cursor(run.manifest.b_start)?),
            _ => None, // interrupted before the first sweep: fresh replay
        };
        let prior: Vec<IterRecord> = run
            .manifest
            .bcd
            .as_ref()
            .map(|p| p.iterations.iter().map(|it| it.to_record()).collect())
            .unwrap_or_default();
        crate::info!(
            "runstore: resuming run {} at sweep {} (budget {} -> {})",
            run.manifest.run_id,
            prior.len(),
            st.budget(),
            b_target
        );
        run.manifest.status = RUNNING.to_string();

        let result = {
            let mut rec = BcdRecorder::new(&mut run);
            run_bcd_resumable_with(
                &self.sess,
                &mut st,
                &self.train_ds,
                b_target,
                &self.exp.bcd,
                0,
                cursor.as_ref(),
                &mut |ev| rec.observe(ev),
                scan,
            )
        };
        let (mut out, run) = self.seal(run, result)?;
        let mut iterations = prior;
        iterations.append(&mut out.iterations);
        out.iterations = iterations;
        out.final_budget = st.budget();
        Ok((st, out, run))
    }

    /// Common epilogue: flip the manifest to its terminal status.
    fn seal(
        &self,
        mut run: RunDir,
        result: Result<BcdOutcome>,
    ) -> Result<(BcdOutcome, RunDir)> {
        match result {
            Ok(out) => {
                run.manifest.status = COMPLETE.to_string();
                run.save()?;
                Ok((out, run))
            }
            Err(e) => {
                run.manifest.status = FAILED.to_string();
                if let Err(save_err) = run.save() {
                    crate::warnlog!(
                        "runstore: could not mark {} failed: {save_err:#}",
                        run.manifest.run_id
                    );
                }
                Err(e)
            }
        }
    }

    /// Test-set accuracy [%] of a state.
    pub fn test_acc(&self, st: &ModelState) -> Result<f64> {
        test_accuracy(&self.sess, st, &self.test_ds)
    }
}
