//! Experiment pipeline: the composition layer every bench, example and CLI
//! subcommand shares.
//!
//! A [`Pipeline`] owns one (backend session, dataset pair, experiment
//! config) triple and produces the staged models of the paper's protocol:
//!
//! ```text
//! baseline (full ReLUs, trained)        -> pipeline.baseline()
//!   └─ SNL reference at B_ref           -> pipeline.snl_ref(b_ref)
//!        └─ BCD down to B_target        -> pipeline.bcd_from(&ref, b_target)
//!   └─ AutoReP reference at B_ref (poly)-> pipeline.autorep_ref(b_ref)
//! ```
//!
//! Expensive stages are cached in the model zoo keyed by (model, dataset,
//! stage, budget, seed) so figure benches that share prefixes don't retrain.

use crate::config::Experiment;
use crate::coordinator::bcd::{run_bcd, BcdOutcome};
use crate::coordinator::eval::test_accuracy;
use crate::coordinator::train::train;
use crate::data::{synth, Dataset};
use crate::methods::autorep::{run_autorep, AutorepConfig};
use crate::methods::snl::run_snl;
use crate::model::{zoo, ModelState};
use crate::runtime::backend::Backend;
use crate::runtime::session::Session;
use anyhow::{anyhow, Context, Result};
use std::path::PathBuf;

/// One experiment's shared context.
pub struct Pipeline<'e> {
    pub sess: Session<'e>,
    pub exp: Experiment,
    pub train_ds: Dataset,
    pub test_ds: Dataset,
    zoo_dir: PathBuf,
}

impl<'e> Pipeline<'e> {
    pub fn new(backend: &'e dyn Backend, exp: Experiment) -> Result<Pipeline<'e>> {
        let sess = Session::new(backend, &exp.model_key())
            .with_context(|| format!("experiment wants model {}", exp.model_key()))?;
        let spec = synth::by_name(&exp.dataset)
            .ok_or_else(|| anyhow!("unknown dataset {:?}", exp.dataset))?;
        let (train_ds, test_ds) = synth::generate(spec);
        // Namespace the zoo by backend: checkpoints from different backends
        // share model keys but not numerics, and must never cross-pollinate.
        let zoo_dir = PathBuf::from(&exp.out_dir).join("zoo").join(backend.name());
        Ok(Pipeline { sess, exp, train_ds, test_ds, zoo_dir })
    }

    /// Trained full-ReLU baseline (cached).
    pub fn baseline(&self) -> Result<ModelState> {
        let tag = format!(
            "{}_base_s{}_t{}",
            self.exp.dataset, self.exp.train.seed, self.exp.train.steps
        );
        zoo::cached(&self.zoo_dir, self.sess.info(), &tag, || {
            let mut st = self.sess.init_state(self.exp.train.seed as i32)?;
            train(&self.sess, &mut st, &self.train_ds, &self.exp.train)?;
            Ok(st)
        })
    }

    /// SNL reference model at `b_ref` ReLUs, from the baseline (cached).
    /// This is the model BCD starts from — paper Tables 4/5.
    pub fn snl_ref(&self, b_ref: usize) -> Result<ModelState> {
        if b_ref >= self.sess.info().total_relus() {
            return self.baseline(); // degenerate: reference == full network
        }
        let tag = format!(
            "{}_snlref_b{}_s{}",
            self.exp.dataset, b_ref, self.exp.snl.seed
        );
        zoo::cached(&self.zoo_dir, self.sess.info(), &tag, || {
            let mut st = self.baseline()?;
            run_snl(&self.sess, &mut st, &self.train_ds, b_ref, &self.exp.snl, 0)?;
            Ok(st)
        })
    }

    /// AutoReP reference model at `b_ref` ReLUs (poly variants; cached).
    pub fn autorep_ref(&self, b_ref: usize) -> Result<ModelState> {
        if b_ref >= self.sess.info().total_relus() {
            return self.baseline();
        }
        let tag = format!(
            "{}_arpref_b{}_s{}",
            self.exp.dataset, b_ref, self.exp.snl.seed
        );
        let cfg = AutorepConfig { base: self.exp.snl.clone(), ..Default::default() };
        zoo::cached(&self.zoo_dir, self.sess.info(), &tag, || {
            let mut st = self.baseline()?;
            run_autorep(&self.sess, &mut st, &self.train_ds, b_ref, &cfg)?;
            Ok(st)
        })
    }

    /// Run BCD from a copy of `reference` down to `b_target`; returns the
    /// reduced state and the iteration trace.
    pub fn bcd_from(
        &self,
        reference: &ModelState,
        b_target: usize,
    ) -> Result<(ModelState, BcdOutcome)> {
        let mut st = reference.clone();
        let out = run_bcd(&self.sess, &mut st, &self.train_ds, b_target, &self.exp.bcd, 0)?;
        Ok((st, out))
    }

    /// Zoo-cached BCD: like [`Self::bcd_from`] but keyed on the run's
    /// determinants (dataset, reference budget, target, BCD knobs, seed) so
    /// benches sharing a configuration don't recompute. The iteration trace
    /// is not cached — use `bcd_from` when you need it.
    pub fn bcd_cached(&self, reference: &ModelState, b_target: usize) -> Result<ModelState> {
        let b = &self.exp.bcd;
        // Non-default schedule/granularity are tagged explicitly; the paper
        // configuration keeps the plain tag (stable across releases).
        let variant = if b.drc_schedule == crate::config::DrcSchedule::Constant
            && b.granularity == crate::config::Granularity::Pixel
        {
            String::new()
        } else {
            format!("_{:?}{:?}", b.drc_schedule, b.granularity)
        };
        let tag = format!(
            "{}_bcd_r{}_t{}_d{}{}_rt{}_a{}_f{}_s{}",
            self.exp.dataset,
            reference.budget(),
            b_target,
            b.drc,
            variant,
            b.rt,
            b.adt,
            b.finetune_steps,
            b.seed
        );
        zoo::cached(&self.zoo_dir, self.sess.info(), &tag, || {
            Ok(self.bcd_from(reference, b_target)?.0)
        })
    }

    /// Test-set accuracy [%] of a state.
    pub fn test_acc(&self, st: &ModelState) -> Result<f64> {
        test_accuracy(&self.sess, st, &self.test_ds)
    }
}
