//! Result recording: CSV emission + terminal ASCII plots.
//!
//! Every bench regenerates a paper table/figure by printing paper-style
//! rows AND writing `results/<id>.csv`; figures additionally render as
//! ASCII line charts so the "shape" criteria in DESIGN.md §5 are visible
//! in the terminal.

use anyhow::{Context, Result};
use std::path::Path;

/// Write a CSV file (creates parent dirs). Values are escaped minimally —
/// our cells are numbers and identifiers.
pub fn write_csv(path: &Path, header: &[&str], rows: &[Vec<String>]) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut out = String::new();
    out.push_str(&header.join(","));
    out.push('\n');
    for row in rows {
        debug_assert_eq!(row.len(), header.len(), "csv row arity");
        out.push_str(&row.join(","));
        out.push('\n');
    }
    std::fs::write(path, out).with_context(|| format!("writing {path:?}"))?;
    crate::info!("wrote {path:?} ({} rows)", rows.len());
    Ok(())
}

/// A named (x, y) series for plotting.
#[derive(Clone, Debug)]
pub struct Series {
    pub label: String,
    pub points: Vec<(f64, f64)>,
}

impl Series {
    pub fn new(label: &str, points: Vec<(f64, f64)>) -> Series {
        Series { label: label.to_string(), points }
    }
}

const MARKS: &[char] = &['o', 'x', '+', '*', '#', '@'];

/// Render series as an ASCII chart (the terminal analog of a paper figure).
pub fn ascii_plot(title: &str, series: &[Series], width: usize, height: usize) -> String {
    let pts: Vec<(f64, f64)> = series.iter().flat_map(|s| s.points.iter().copied()).collect();
    if pts.is_empty() {
        return format!("{title}\n  (no data)\n");
    }
    let (mut x0, mut x1) = (f64::MAX, f64::MIN);
    let (mut y0, mut y1) = (f64::MAX, f64::MIN);
    for &(x, y) in &pts {
        x0 = x0.min(x);
        x1 = x1.max(x);
        y0 = y0.min(y);
        y1 = y1.max(y);
    }
    if (x1 - x0).abs() < 1e-12 {
        x1 = x0 + 1.0;
    }
    if (y1 - y0).abs() < 1e-12 {
        y1 = y0 + 1.0;
    }
    let mut grid = vec![vec![' '; width]; height];
    for (si, s) in series.iter().enumerate() {
        let mark = MARKS[si % MARKS.len()];
        for &(x, y) in &s.points {
            let cx = ((x - x0) / (x1 - x0) * (width - 1) as f64).round() as usize;
            let cy = ((y - y0) / (y1 - y0) * (height - 1) as f64).round() as usize;
            grid[height - 1 - cy][cx] = mark;
        }
    }
    let mut out = format!("{title}\n");
    out.push_str(&format!("{y1:>10.2} ┤"));
    out.push_str(&grid[0].iter().collect::<String>());
    out.push('\n');
    for row in &grid[1..height - 1] {
        out.push_str("           │");
        out.push_str(&row.iter().collect::<String>());
        out.push('\n');
    }
    out.push_str(&format!("{y0:>10.2} ┤"));
    out.push_str(&grid[height - 1].iter().collect::<String>());
    out.push('\n');
    out.push_str(&format!(
        "           └{}\n            {:<10.2}{:>w$.2}\n",
        "─".repeat(width),
        x0,
        x1,
        w = width - 10
    ));
    for (si, s) in series.iter().enumerate() {
        out.push_str(&format!("            {} {}\n", MARKS[si % MARKS.len()], s.label));
    }
    out
}

/// Paper-style table printer: fixed-width columns from string rows.
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, c) in row.iter().enumerate() {
            widths[i] = widths[i].max(c.len());
        }
    }
    println!("\n{title}");
    let line: String = header
        .iter()
        .enumerate()
        .map(|(i, h)| format!("{:<w$}", h, w = widths[i] + 2))
        .collect();
    println!("{line}");
    println!("{}", "-".repeat(line.len()));
    for row in rows {
        let line: String = row
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:<w$}", c, w = widths[i] + 2))
            .collect();
        println!("{line}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip() {
        let path = std::env::temp_dir().join("cdnl_metrics_test/t.csv");
        write_csv(
            &path,
            &["a", "b"],
            &[vec!["1".into(), "2".into()], vec!["3".into(), "4".into()]],
        )
        .unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1,2\n3,4\n");
    }

    #[test]
    fn plot_contains_marks_and_labels() {
        let p = ascii_plot(
            "fig",
            &[Series::new("ours", vec![(0.0, 1.0), (1.0, 2.0)])],
            20,
            6,
        );
        assert!(p.contains('o'));
        assert!(p.contains("ours"));
    }

    #[test]
    fn plot_handles_degenerate_ranges() {
        let p = ascii_plot("f", &[Series::new("s", vec![(1.0, 1.0)])], 10, 4);
        assert!(p.contains('o'));
        let empty = ascii_plot("f", &[], 10, 4);
        assert!(empty.contains("no data"));
    }
}
