//! Block Coordinate Descent over binary ReLU masks — Algorithm 2, the
//! paper's contribution.
//!
//! Starting from a reference network with `B_ref` active ReLUs, iterate
//! `T = ceil((B_ref - B_target) / DRC)` times: scan up to RT random
//! hypotheses that each remove DRC present ReLUs, keep the one with least
//! proxy-accuracy degradation (early-accepting under ADT), apply it
//! permanently — removed ReLUs are never revisited, so every intermediate
//! state is sparse by design — then finetune with cosine-annealed SGD.
//!
//! The per-iteration hypothesis scan fans out across `cfg.workers` threads
//! (see [`crate::coordinator::trials`]); results are bit-identical for any
//! worker count, so runs replay exactly regardless of the machine.

use crate::config::BcdConfig;
use crate::coordinator::eval::Evaluator;
use crate::coordinator::finetune::{finetune, FinetuneStats};
use crate::coordinator::trials::{scan_trials, BlockSampler, ScanOutcome};
use crate::data::Dataset;
use crate::model::{Mask, ModelState};
use crate::runtime::session::Session;
use crate::util::prng::Rng;
use anyhow::{bail, Result};

/// Per-iteration record (feeds the ablation figures and EXPERIMENTS.md).
#[derive(Clone, Debug)]
pub struct IterRecord {
    pub t: usize,
    pub budget_after: usize,
    pub base_acc: f64,
    pub chosen_dacc: f64,
    pub trials_evaluated: usize,
    pub trials_bounded: usize,
    pub early_accept: bool,
    pub finetune: FinetuneStats,
}

/// Outcome of a full BCD run.
#[derive(Clone, Debug)]
pub struct BcdOutcome {
    pub iterations: Vec<IterRecord>,
    /// Mask snapshots (dense) taken every `snapshot_every` iterations, for
    /// the IoU dynamics analysis (Fig. 6 analog).
    pub snapshots: Vec<(usize, Mask)>,
    pub final_budget: usize,
    pub wall_secs: f64,
}

impl BcdOutcome {
    /// Total trial evaluations across the run (the §Perf denominator).
    pub fn total_trials(&self) -> usize {
        self.iterations.iter().map(|r| r.trials_evaluated).sum()
    }
}

/// Run Algorithm 2 on `st` until `||m||_0 == b_target`, mutating it.
///
/// `train_ds` provides both the trial proxy batches and finetune batches.
/// Set `snapshot_every > 0` to record mask snapshots for mask-dynamics
/// analysis.
pub fn run_bcd(
    sess: &Session,
    st: &mut ModelState,
    train_ds: &Dataset,
    b_target: usize,
    cfg: &BcdConfig,
    snapshot_every: usize,
) -> Result<BcdOutcome> {
    let b_ref = st.budget();
    if b_target >= b_ref {
        bail!("BCD: target budget {b_target} >= current budget {b_ref}");
    }
    if cfg.drc == 0 || cfg.rt == 0 {
        bail!("BCD: drc and rt must be positive");
    }
    let t_est = (b_ref - b_target).div_ceil(cfg.drc);
    let workers = cfg.effective_workers();
    crate::info!(
        "bcd: {} -> {} ReLUs, T~{} iterations (DRC={} {:?}, RT={}, ADT={}%, {:?}, workers={})",
        b_ref,
        b_target,
        t_est,
        cfg.drc,
        cfg.drc_schedule,
        cfg.rt,
        cfg.adt,
        cfg.granularity,
        workers
    );

    let wall0 = std::time::Instant::now();
    let mut rng = Rng::new(cfg.seed);
    let mut ft_rng = rng.fork(0xF17E);
    let ev = Evaluator::new(sess, train_ds, cfg.proxy_batches)?;
    let sampler = BlockSampler::new(cfg.granularity, sess.info());
    let to_remove_total = b_ref - b_target;
    let mut out = BcdOutcome {
        iterations: Vec::with_capacity(t_est),
        snapshots: Vec::new(),
        final_budget: b_ref,
        wall_secs: 0.0,
    };

    let mut t = 0usize;
    while st.budget() > b_target {
        t += 1;
        // Schedule-driven DRC; the last iteration may need fewer removals
        // to land exactly on the target.
        let drc = cfg
            .drc_schedule
            .drc_at(cfg.drc, cfg.drc_final, b_ref - st.budget(), to_remove_total)
            .min(st.budget() - b_target);
        // Params changed in the previous finetune: upload once per iteration.
        let params = ev.upload_params(&st.params)?;
        let base_acc = ev.accuracy(&params, st.mask.dense())?;

        let ScanOutcome { chosen, evaluated, bounded, early_accept } = scan_trials(
            &ev, &params, &st.mask, &sampler, drc, cfg.rt, cfg.adt, base_acc, &mut rng, workers,
        )?;
        st.mask.apply_removal(&chosen.removed)?;

        let ft = finetune(
            sess,
            st,
            train_ds,
            cfg.finetune_steps,
            cfg.finetune_lr,
            &mut ft_rng,
        )?;

        crate::info!(
            "bcd t={t}: budget={} base={base_acc:.2}% dAcc={:+.2} trials={evaluated} ({bounded} bounded{}) ft_loss {:.3}->{:.3}",
            st.budget(),
            chosen.dacc,
            if early_accept { ", early" } else { "" },
            ft.first_loss,
            ft.last_loss
        );

        out.iterations.push(IterRecord {
            t,
            budget_after: st.budget(),
            base_acc,
            chosen_dacc: chosen.dacc,
            trials_evaluated: evaluated,
            trials_bounded: bounded,
            early_accept,
            finetune: ft,
        });
        if snapshot_every > 0 && (t % snapshot_every == 0 || st.budget() == b_target) {
            out.snapshots.push((st.budget(), st.mask.clone()));
        }
    }

    debug_assert_eq!(st.budget(), b_target);
    out.final_budget = st.budget();
    out.wall_secs = wall0.elapsed().as_secs_f64();
    Ok(out)
}
