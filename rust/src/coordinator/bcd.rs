//! Block Coordinate Descent over binary ReLU masks — Algorithm 2, the
//! paper's contribution.
//!
//! Starting from a reference network with `B_ref` active ReLUs, iterate
//! `T = ceil((B_ref - B_target) / DRC)` times: scan up to RT random
//! hypotheses that each remove DRC present ReLUs, keep the one with least
//! proxy-accuracy degradation (early-accepting under ADT), apply it
//! permanently — removed ReLUs are never revisited, so every intermediate
//! state is sparse by design — then finetune with cosine-annealed SGD.
//!
//! The per-iteration hypothesis scan fans out across `cfg.workers` threads
//! (see [`crate::coordinator::trials`]); results are bit-identical for any
//! worker count, so runs replay exactly regardless of the machine.
//!
//! # Checkpointing and resume
//!
//! A sweep is the unit of durability. [`run_bcd_resumable`] calls a
//! [`SweepHook`] after every completed sweep with a [`SweepEvent`]: the
//! iteration record, the removed indices, the post-sweep [`ModelState`],
//! and a [`BcdCursor`] — the loop-carried coordinates (sweep count,
//! original `B_ref`, both RNG states) that, together with the state, fully
//! determine the remainder of the run. The run-store
//! ([`crate::runstore`]) persists these; feeding the cursor back via
//! `resume` continues an interrupted run bit-identically to one that never
//! stopped (DESIGN.md §6).

use crate::config::BcdConfig;
use crate::coordinator::eval::{EvalOpts, Evaluator};
use crate::coordinator::finetune::{finetune, FinetuneStats};
use crate::coordinator::trials::{scan_trials, BlockSampler, ScanOutcome};
use crate::data::Dataset;
use crate::model::{Mask, ModelState};
use crate::runtime::backend::DeviceBuf;
use crate::tensor::Tensor;
use crate::runtime::session::Session;
use crate::util::prng::Rng;
use anyhow::{bail, Result};

/// Per-iteration record (feeds the ablation figures and EXPERIMENTS.md).
#[derive(Clone, Debug)]
pub struct IterRecord {
    pub t: usize,
    pub budget_after: usize,
    pub base_acc: f64,
    pub chosen_dacc: f64,
    pub trials_evaluated: usize,
    pub trials_bounded: usize,
    pub early_accept: bool,
    pub finetune: FinetuneStats,
    /// Wall-clock of this sweep (scan + finetune) in milliseconds. Not part
    /// of the replay contract — timing differs between a resumed and an
    /// uninterrupted run even when every numeric result is identical.
    pub wall_ms: f64,
}

/// Outcome of a full BCD run.
#[derive(Clone, Debug)]
pub struct BcdOutcome {
    pub iterations: Vec<IterRecord>,
    /// Mask snapshots (dense) taken every `snapshot_every` iterations, for
    /// the IoU dynamics analysis (Fig. 6 analog).
    pub snapshots: Vec<(usize, Mask)>,
    pub final_budget: usize,
    pub wall_secs: f64,
}

impl BcdOutcome {
    /// Total trial evaluations across the run (the §Perf denominator).
    pub fn total_trials(&self) -> usize {
        self.iterations.iter().map(|r| r.trials_evaluated).sum()
    }
}

/// The loop-carried coordinates of a BCD run after some number of completed
/// sweeps. Everything beyond the [`ModelState`] that [`run_bcd_resumable`]
/// needs to continue exactly where a previous process stopped.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BcdCursor {
    /// Completed sweeps so far (the next sweep is `sweeps_done + 1`).
    pub sweeps_done: usize,
    /// The budget the run *started* from — the DRC schedule is positioned
    /// by progress relative to this, so it must survive interruption.
    pub b_ref: usize,
    /// Trial-sampling RNG state after the last completed sweep.
    pub rng: [u64; 4],
    /// Finetune-batch RNG state after the last completed sweep.
    pub ft_rng: [u64; 4],
}

/// Everything a checkpoint hook sees after one completed sweep.
pub struct SweepEvent<'a> {
    /// Cursor positioned *after* this sweep.
    pub cursor: BcdCursor,
    pub record: &'a IterRecord,
    /// Flat ReLU indices this sweep removed (the BCD trace entry).
    pub removed: &'a [usize],
    /// Model state after removal + finetune.
    pub state: &'a ModelState,
    /// True when this sweep landed on the target budget.
    pub done: bool,
}

/// Called after every completed sweep; returning an error aborts the run
/// (the checkpoint written for this sweep remains valid for resume).
pub type SweepHook<'h> = dyn FnMut(&SweepEvent) -> Result<()> + 'h;

/// Everything one iteration's trial scan needs, bundled so the scan itself
/// is pluggable: the local thread pool ([`local_scanner`]) and the
/// distributed coordinator ([`crate::dist`]) implement the same contract
/// and must produce bit-identical [`ScanOutcome`]s (DESIGN.md §15).
pub struct ScanArgs<'a, 'e, 's> {
    pub ev: &'a Evaluator<'e, 's>,
    /// Current params, already uploaded to the local backend.
    pub params: &'a DeviceBuf,
    /// The same params host-side (distributed scans publish these to CAS).
    pub params_host: &'a Tensor,
    pub mask: &'a Mask,
    pub sampler: &'a BlockSampler<'a>,
    /// Removals per hypothesis this iteration (schedule-driven).
    pub drc: usize,
    /// The iteration's pre-removal proxy accuracy.
    pub base_acc: f64,
    /// 1-based sweep number (a fresh scan generation id per iteration).
    pub sweep: usize,
}

/// A pluggable trial scan: given the iteration bundle and the trial RNG
/// (positioned exactly as Algorithm 2 requires), produce the iteration's
/// [`ScanOutcome`]. Implementations MUST consume RNG state identically to
/// [`scan_trials`] — all `rt` forks, nothing else — or resume breaks.
pub type TrialScanner<'h> = dyn FnMut(&ScanArgs, &mut Rng) -> Result<ScanOutcome> + 'h;

/// Identity helper pinning the closure to the higher-ranked `TrialScanner`
/// signature (so `&mut local_scanner(cfg)` coerces to `&mut TrialScanner`).
pub fn as_scanner<F>(f: F) -> F
where
    F: FnMut(&ScanArgs, &mut Rng) -> Result<ScanOutcome>,
{
    f
}

/// The default scan substrate: [`scan_trials`] across `cfg.effective_workers()`
/// local threads.
pub fn local_scanner(
    cfg: &BcdConfig,
) -> impl FnMut(&ScanArgs, &mut Rng) -> Result<ScanOutcome> + '_ {
    let workers = cfg.effective_workers();
    as_scanner(move |a: &ScanArgs, rng: &mut Rng| {
        scan_trials(
            a.ev, a.params, a.mask, a.sampler, a.drc, cfg.rt, cfg.adt, a.base_acc, rng, workers,
        )
    })
}

/// Run Algorithm 2 on `st` until `||m||_0 == b_target`, mutating it.
///
/// `train_ds` provides both the trial proxy batches and finetune batches.
/// Set `snapshot_every > 0` to record mask snapshots for mask-dynamics
/// analysis.
pub fn run_bcd(
    sess: &Session,
    st: &mut ModelState,
    train_ds: &Dataset,
    b_target: usize,
    cfg: &BcdConfig,
    snapshot_every: usize,
) -> Result<BcdOutcome> {
    run_bcd_resumable(sess, st, train_ds, b_target, cfg, snapshot_every, None, &mut |_| Ok(()))
}

/// [`run_bcd`] with checkpoint hooks: `on_sweep` fires after every
/// completed sweep, and `resume` continues a run from a persisted
/// [`BcdCursor`] (with `st` being the matching checkpointed state).
///
/// The resumed trajectory is **bit-identical** to the uninterrupted one:
/// the cursor carries both RNG streams mid-sequence and the original
/// `b_ref` (which positions the DRC schedule), and everything else the loop
/// reads is a pure function of `(st, cfg, train_ds)`. Verified end-to-end
/// in `rust/tests/integration_runstore.rs`.
#[allow(clippy::too_many_arguments)]
pub fn run_bcd_resumable(
    sess: &Session,
    st: &mut ModelState,
    train_ds: &Dataset,
    b_target: usize,
    cfg: &BcdConfig,
    snapshot_every: usize,
    resume: Option<&BcdCursor>,
    on_sweep: &mut SweepHook,
) -> Result<BcdOutcome> {
    let mut scan = local_scanner(cfg);
    run_bcd_resumable_with(
        sess, st, train_ds, b_target, cfg, snapshot_every, resume, on_sweep, &mut scan,
    )
}

/// [`run_bcd_resumable`] with a pluggable per-iteration scan substrate
/// (local thread pool or the distributed coordinator — the outer loop,
/// checkpointing, and resume semantics are identical either way, which is
/// what makes a distributed run resumable from the same `run.json` cursors
/// as a local one).
#[allow(clippy::too_many_arguments)]
pub fn run_bcd_resumable_with(
    sess: &Session,
    st: &mut ModelState,
    train_ds: &Dataset,
    b_target: usize,
    cfg: &BcdConfig,
    snapshot_every: usize,
    resume: Option<&BcdCursor>,
    on_sweep: &mut SweepHook,
    scan: &mut TrialScanner,
) -> Result<BcdOutcome> {
    let (b_ref, mut t, mut rng, mut ft_rng) = match resume {
        Some(c) => (
            c.b_ref,
            c.sweeps_done,
            Rng::from_state(c.rng),
            Rng::from_state(c.ft_rng),
        ),
        None => {
            // Fresh run: fork the finetune stream off the trial stream
            // exactly once, up front (order matters for replay).
            let mut rng = Rng::new(cfg.seed);
            let ft_rng = rng.fork(0xF17E);
            (st.budget(), 0, rng, ft_rng)
        }
    };
    if resume.is_some() && st.budget() == b_target {
        // The interruption landed exactly on completion; nothing to do.
        return Ok(BcdOutcome {
            iterations: Vec::new(),
            snapshots: Vec::new(),
            final_budget: b_target,
            wall_secs: 0.0,
        });
    }
    if b_target >= st.budget() {
        bail!("BCD: target budget {b_target} >= current budget {}", st.budget());
    }
    if cfg.drc == 0 || cfg.rt == 0 {
        bail!("BCD: drc and rt must be positive");
    }
    let t_est = (b_ref - b_target).div_ceil(cfg.drc);
    let workers = cfg.effective_workers();
    crate::info!(
        "bcd: {} -> {} ReLUs, T~{} iterations (DRC={} {:?}, RT={}, ADT={}%, {:?}, workers={}{})",
        st.budget(),
        b_target,
        t_est,
        cfg.drc,
        cfg.drc_schedule,
        cfg.rt,
        cfg.adt,
        cfg.granularity,
        workers,
        if t > 0 { format!(", resumed at sweep {t}") } else { String::new() }
    );

    let wall0 = std::time::Instant::now();
    // The hot-path evaluator carries the prefix-activation cache
    // (`bcd.cache_mb`, 0 = full forwards only), the hypothesis-slab width
    // (`bcd.trial_batch`) and the release-mode verification knobs
    // (`bcd.verify_staged`, `bcd.verify_lowering`); staged, batched,
    // lowered and full scoring are all bit-identical, so none of these
    // knobs ever move results (DESIGN.md §8, §11, §13).
    let ev = Evaluator::with_opts(
        sess,
        train_ds,
        cfg.proxy_batches,
        EvalOpts {
            cache_bytes: cfg.cache_mb.saturating_mul(1 << 20),
            trial_batch: cfg.trial_batch,
            verify_staged: cfg.verify_staged,
            verify_lowering: cfg.verify_lowering,
        },
    )?;
    let sampler = BlockSampler::new(cfg.granularity, sess.info());
    let to_remove_total = b_ref - b_target;
    let mut out = BcdOutcome {
        iterations: Vec::with_capacity(t_est.saturating_sub(t)),
        snapshots: Vec::new(),
        final_budget: b_ref,
        wall_secs: 0.0,
    };

    while st.budget() > b_target {
        t += 1;
        let sweep0 = std::time::Instant::now();
        // Schedule-driven DRC; the last iteration may need fewer removals
        // to land exactly on the target.
        let drc = cfg
            .drc_schedule
            .drc_at(cfg.drc, cfg.drc_final, b_ref - st.budget(), to_remove_total)
            .min(st.budget() - b_target);
        // Params changed in the previous finetune: upload once per iteration.
        let params = ev.upload_params(&st.params)?;
        let base_acc = ev.accuracy(&params, st.mask.dense())?;

        let args = ScanArgs {
            ev: &ev,
            params: &params,
            params_host: &st.params,
            mask: &st.mask,
            sampler: &sampler,
            drc,
            base_acc,
            sweep: t,
        };
        let ScanOutcome { chosen, evaluated, bounded, early_accept } = scan(&args, &mut rng)?;
        st.mask.apply_removal(&chosen.removed)?;

        let ft = finetune(
            sess,
            st,
            train_ds,
            cfg.finetune_steps,
            cfg.finetune_lr,
            &mut ft_rng,
        )?;

        crate::info!(
            "bcd t={t}: budget={} base={base_acc:.2}% dAcc={:+.2} trials={evaluated} ({bounded} bounded{}) ft_loss {:.3}->{:.3}",
            st.budget(),
            chosen.dacc,
            if early_accept { ", early" } else { "" },
            ft.first_loss,
            ft.last_loss
        );

        out.iterations.push(IterRecord {
            t,
            budget_after: st.budget(),
            base_acc,
            chosen_dacc: chosen.dacc,
            trials_evaluated: evaluated,
            trials_bounded: bounded,
            early_accept,
            finetune: ft,
            wall_ms: 1e3 * sweep0.elapsed().as_secs_f64(),
        });
        if snapshot_every > 0 && (t % snapshot_every == 0 || st.budget() == b_target) {
            out.snapshots.push((st.budget(), st.mask.clone()));
        }
        let done = st.budget() == b_target;
        on_sweep(&SweepEvent {
            cursor: BcdCursor {
                sweeps_done: t,
                b_ref,
                rng: rng.state(),
                ft_rng: ft_rng.state(),
            },
            record: out.iterations.last().expect("just pushed"),
            removed: &chosen.removed,
            state: st,
            done,
        })?;
    }

    debug_assert_eq!(st.budget(), b_target);
    out.final_budget = st.budget();
    out.wall_secs = wall0.elapsed().as_secs_f64();
    Ok(out)
}
