//! The random-trial scheduler inside one BCD iteration (Algorithm 2,
//! lines 7–20): sample DRC present ReLUs, score the hypothesis, early-accept
//! under ADT, otherwise keep the argmin-degradation candidate.
//!
//! # Parallel scan
//!
//! Hypothesis scoring dominates BCD wall-clock, so [`scan_trials`] fans the
//! RT hypotheses across a scoped worker pool, and each hypothesis travels
//! as a sparse [`MaskDelta`] so the evaluator can resume its forward pass
//! from cached base-mask activations when the delta leaves the early layers
//! clean (staged execution, DESIGN.md §8 — incremental scoring is
//! bit-identical to full forwards, so nothing below changes). Determinism
//! is preserved by construction — the outcome is **bit-identical for every
//! worker count**:
//!
//! 1. All RT draws are made up front on the caller's thread, each from an
//!    RNG forked by trial index, and deduplicated in draw order.
//! 2. Workers claim contiguous *slabs* of up to `bcd.trial_batch` trial
//!    indices strictly in order from shared state and score them with the
//!    early-exit bound, using as floor the best accuracy among *completed
//!    below-slab-start* trials (a conservative subset of the floor a
//!    sequential scan would have for every slab member, so anything the
//!    runtime cuts, a sequential scan would cut too). Slab members are
//!    batched into shared backend calls by [`Evaluator::eval_trial_slab`]
//!    (DESIGN.md §11), bit-identically to scoring them one by one. Once
//!    some trial passes the ADT accept test, no indices beyond it are
//!    claimed.
//! 3. A sequential **replay merge** over the per-trial results re-applies
//!    Algorithm 2's exact decision sequence (incumbent floor, bound,
//!    early-accept, argmin with ties to the lowest index) using the
//!    recorded per-batch correct counts, yielding the same `ScanOutcome` a
//!    single-threaded scan produces.

use crate::config::Granularity;
use crate::coordinator::eval::{Evaluator, TrialEval};
use crate::model::{Mask, MaskDelta};
use crate::runtime::manifest::ModelInfo;
use crate::util::prng::Rng;
use anyhow::Result;
use std::collections::HashSet;
use std::sync::Mutex;

/// Draws one DRC-sized removal hypothesis at the configured granularity.
pub struct BlockSampler<'a> {
    granularity: Granularity,
    info: &'a ModelInfo,
}

impl<'a> BlockSampler<'a> {
    pub fn new(granularity: Granularity, info: &'a ModelInfo) -> BlockSampler<'a> {
        BlockSampler { granularity, info }
    }

    /// Sample exactly `drc` present ReLU indices to remove.
    pub fn sample(&self, mask: &Mask, rng: &mut Rng, drc: usize) -> Vec<usize> {
        match self.granularity {
            Granularity::Pixel => mask.sample_present(rng, drc),
            Granularity::Channel => self.sample_channels(mask, rng, drc),
        }
    }

    /// Channel blocks: draw whole channels (H*W consecutive flat indices)
    /// until `drc` ReLUs accumulate; the final channel is truncated to a
    /// random subset so the hypothesis removes exactly `drc` (keeping the
    /// exact-landing invariant of Algorithm 2).
    fn sample_channels(&self, mask: &Mask, rng: &mut Rng, drc: usize) -> Vec<usize> {
        // Channels that still hold present ReLUs, as (start, end) ranges.
        let mut channels: Vec<(usize, usize)> = Vec::new();
        for e in &self.info.mask_layers {
            let (c, hw) = (e.shape[0], e.size / e.shape[0]);
            for ci in 0..c {
                let start = e.offset + ci * hw;
                if (start..start + hw).any(|i| mask.is_present(i)) {
                    channels.push((start, start + hw));
                }
            }
        }
        rng.shuffle(&mut channels);
        let mut removed = Vec::with_capacity(drc);
        for (start, end) in channels {
            if removed.len() == drc {
                break;
            }
            let mut present: Vec<usize> =
                (start..end).filter(|&i| mask.is_present(i)).collect();
            let need = drc - removed.len();
            if present.len() > need {
                rng.shuffle(&mut present);
                present.truncate(need);
            }
            removed.extend(present);
        }
        assert_eq!(removed.len(), drc, "not enough present ReLUs for DRC={drc}");
        removed
    }
}

/// One scored mask hypothesis.
#[derive(Clone, Debug, PartialEq)]
pub struct Trial {
    /// Flat ReLU indices this hypothesis removes.
    pub removed: Vec<usize>,
    /// Proxy accuracy [%] with the hypothesis applied.
    pub acc: f64,
    /// Degradation vs. the iteration's base accuracy (percentage points).
    pub dacc: f64,
}

/// Result of one iteration's trial scan.
#[derive(Clone, Debug, PartialEq)]
pub struct ScanOutcome {
    pub chosen: Trial,
    /// Trials actually evaluated (<= RT; early-accept can cut it short).
    pub evaluated: usize,
    /// Trials aborted early by the accuracy bound (§Perf).
    pub bounded: usize,
    /// Whether the chosen trial passed the ADT early-accept test.
    pub early_accept: bool,
}

/// Worker-shared scan state: in-order claim counter, per-trial results, and
/// the lowest accept index observed so far (the shared stop signal; the
/// completed lower-index accuracies double as the shared early-exit floor).
///
/// `pub(crate)` so the distributed coordinator ([`crate::dist`]) can wrap the
/// exact same claim semantics in a lease layer — remote slabs are granted by
/// this struct, so local and distributed scans claim identically.
pub(crate) struct ScanState {
    pub(crate) next: usize,
    pub(crate) stop_at: Option<usize>,
    pub(crate) results: Vec<Option<TrialEval>>,
}

impl ScanState {
    pub(crate) fn new(n: usize) -> ScanState {
        ScanState { next: 0, stop_at: None, results: vec![None; n] }
    }

    /// Claim the next contiguous slab of up to `max` trial indices, plus the
    /// bound floor valid for it: the best accuracy among completed trials
    /// with an index *below the slab start*. Restricting the floor to
    /// lower-than-start indices keeps runtime cuts a subset of sequential
    /// cuts for EVERY member of the slab (the sequential floor only grows
    /// with the index), so the replay merge's determinism argument is
    /// unchanged at any slab width — `claim_slab(1)` is exactly the old
    /// single-index claim. Claims never extend past the accept index.
    pub(crate) fn claim_slab(&mut self, max: usize) -> Option<(usize, usize, f64)> {
        debug_assert!(max >= 1);
        if self.next >= self.results.len() {
            return None;
        }
        if let Some(stop) = self.stop_at {
            if self.next > stop {
                return None;
            }
        }
        let start = self.next;
        let mut end = (start + max).min(self.results.len());
        if let Some(stop) = self.stop_at {
            end = end.min(stop + 1);
        }
        self.next = end;
        let mut floor = 0.0f64;
        for r in &self.results[..start] {
            if let Some(TrialEval::Scored { acc, .. }) = r {
                floor = floor.max(*acc);
            }
        }
        Some((start, end - start, floor))
    }
}

/// Phase 1 of a trial scan, shared verbatim by the local pool and the
/// distributed coordinator ([`crate::dist`]): draw all `rt` hypotheses up
/// front, each from a trial-index fork of the iteration RNG, deduplicating
/// in draw order (a duplicate draw never burns an evaluation, exactly as in
/// the sequential Algorithm 2 loop). Consumes identical RNG state wherever
/// it runs — the determinism anchor for any execution substrate.
pub fn draw_hypotheses(
    mask: &Mask,
    sampler: &BlockSampler,
    drc: usize,
    rt: usize,
    rng: &mut Rng,
) -> Vec<MaskDelta> {
    let mut seen: HashSet<Vec<usize>> = HashSet::new();
    let mut hyps: Vec<MaskDelta> = Vec::new();
    for t in 0..rt {
        let mut trial_rng = rng.fork(t as u64);
        let mut removed = sampler.sample(mask, &mut trial_rng, drc);
        removed.sort_unstable();
        if seen.insert(removed.clone()) {
            hyps.push(MaskDelta::new(removed));
        }
    }
    hyps
}

/// Phase 3 of a trial scan: the sequential replay merge — Algorithm 2's
/// exact decision sequence (incumbent floor, bound, early-accept, argmin
/// with ties to the lowest index) over recorded per-trial results.
/// Speculative results past the accept index are discarded, and bound
/// decisions are re-derived from the recorded per-batch corrects against
/// the sequential incumbent floor, so the outcome matches a 1-worker scan
/// bit for bit *regardless of which worker — local thread or remote machine
/// — produced each result, and regardless of duplicate or re-issued slabs*
/// (DESIGN.md §15 carries the full argument).
///
/// `would_bound(batch_corrects, floor)` must be the evaluator's bound
/// predicate ([`Evaluator::would_bound`]); it is a parameter so the merge is
/// testable (and usable by the dist coordinator) without a live backend.
pub fn replay_merge(
    hyps: &[MaskDelta],
    results: Vec<Option<TrialEval>>,
    base_acc: f64,
    adt: f64,
    would_bound: impl Fn(&[f64], f64) -> bool,
) -> ScanOutcome {
    let mut best: Option<Trial> = None;
    let mut evaluated = 0usize;
    let mut bounded = 0usize;
    let mut early_accept = false;
    for (i, r) in results.into_iter().enumerate() {
        let Some(r) = r else { break }; // unclaimed tail beyond the stop index
        evaluated += 1;
        match r {
            TrialEval::Bounded => {
                // The runtime floor is never above the sequential floor, so
                // a runtime cut implies a sequential cut.
                bounded += 1;
            }
            TrialEval::Scored { acc, batch_corrects } => {
                let floor = best.as_ref().map(|b| b.acc).unwrap_or(0.0);
                if would_bound(&batch_corrects, floor) {
                    bounded += 1;
                    continue;
                }
                let dacc = base_acc - acc;
                let better = best.as_ref().map(|b| acc > b.acc).unwrap_or(true);
                if better {
                    best = Some(Trial { removed: hyps[i].indices().to_vec(), acc, dacc });
                }
                if dacc < adt {
                    // Algorithm 2 line 11: accept under the tolerance.
                    early_accept = true;
                    break;
                }
            }
        }
    }
    let chosen = best.expect("rt >= 1 and the first trial is never bounded");
    ScanOutcome { chosen, evaluated, bounded, early_accept }
}

/// Scan up to `rt` random DRC-sized hypotheses of `mask` (never mutates it),
/// scoring across `workers` threads (1 = sequential; the outcome is
/// identical either way).
///
/// `base_acc` is the iteration's pre-removal proxy accuracy; `adt` the
/// Accuracy Degradation Tolerance in percentage points. Duplicate draws are
/// skipped without consuming a trial evaluation.
#[allow(clippy::too_many_arguments)]
pub fn scan_trials(
    ev: &Evaluator,
    params: &crate::runtime::backend::DeviceBuf,
    mask: &Mask,
    sampler: &BlockSampler,
    drc: usize,
    rt: usize,
    adt: f64,
    base_acc: f64,
    rng: &mut Rng,
    workers: usize,
) -> Result<ScanOutcome> {
    assert!(drc <= mask.count(), "DRC {drc} > present ReLUs {}", mask.count());
    assert!(rt >= 1, "scan_trials needs rt >= 1");

    // Phase 1 (see `draw_hypotheses`): all RT draws happen here, up front.
    let hyps = draw_hypotheses(mask, sampler, drc, rt, rng);

    // Arm the per-iteration prefix-activation cache (no-op when disabled).
    ev.begin_iteration(mask)?;

    // Phase 2: score across the worker pool. Each worker claims contiguous
    // slabs of up to `slab_max` hypotheses so the evaluator can batch them
    // into shared backend calls (DESIGN.md §11); slab width 1 degenerates to
    // the old one-index-at-a-time loop, and the outcome is bit-identical at
    // any width (see `ScanState::claim_slab` and the replay merge below).
    let n = hyps.len();
    let workers = workers.max(1).min(n);
    let slab_max = ev.slab_width();
    let state = Mutex::new(ScanState::new(n));
    std::thread::scope(|scope| -> Result<()> {
        let mut handles = Vec::with_capacity(workers);
        for _ in 0..workers {
            handles.push(scope.spawn(|| -> Result<()> {
                let mut scratch: Vec<f32> = Vec::with_capacity(mask.size());
                loop {
                    let Some((start, len, floor)) = state.lock().unwrap().claim_slab(slab_max)
                    else {
                        return Ok(());
                    };
                    let evals = ev.eval_trial_slab(
                        params,
                        mask,
                        &hyps[start..start + len],
                        floor,
                        &mut scratch,
                    )?;
                    let mut st = state.lock().unwrap();
                    for (off, result) in evals.into_iter().enumerate() {
                        let i = start + off;
                        if let TrialEval::Scored { acc, .. } = &result {
                            if base_acc - acc < adt {
                                st.stop_at = Some(st.stop_at.map_or(i, |s| s.min(i)));
                            }
                        }
                        st.results[i] = Some(result);
                    }
                }
            }));
        }
        for h in handles {
            h.join().expect("scan worker panicked")?;
        }
        Ok(())
    })?;
    // Mirror this scan's prefix-cache tallies into the backend stats once,
    // off the per-batch hot path.
    ev.flush_cache_stats();

    // Phase 3 (see `replay_merge`): the sequential replay over the recorded
    // results, with the evaluator's bound predicate.
    let results = state.into_inner().unwrap().results;
    Ok(replay_merge(&hyps, results, base_acc, adt, |corrects, floor| {
        ev.would_bound(corrects, floor)
    }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::{ModelInfo, PackEntry};

    fn two_layer_info() -> ModelInfo {
        // Layer 0: 4 channels of 2x2 (16); layer 1: 2 channels of 3x1 (6).
        ModelInfo {
            key: "t".into(),
            backbone: "resnet".into(),
            num_classes: 2,
            image_size: 4,
            channels: 3,
            poly: false,
            param_size: 1,
            mask_size: 22,
            mask_layers: vec![
                PackEntry { name: "a".into(), shape: vec![4, 2, 2], offset: 0, size: 16 },
                PackEntry { name: "b".into(), shape: vec![2, 3, 1], offset: 16, size: 6 },
            ],
            param_entries: vec![],
            artifacts: Default::default(),
        }
    }

    #[test]
    fn pixel_sampler_draws_present_only() {
        let info = two_layer_info();
        let sampler = BlockSampler::new(Granularity::Pixel, &info);
        let mut mask = Mask::full(22);
        mask.remove(0).unwrap();
        let mut rng = Rng::new(1);
        for _ in 0..50 {
            let s = sampler.sample(&mask, &mut rng, 5);
            assert_eq!(s.len(), 5);
            assert!(s.iter().all(|&i| mask.is_present(i)));
        }
    }

    #[test]
    fn channel_sampler_exact_count_and_block_structure() {
        let info = two_layer_info();
        let sampler = BlockSampler::new(Granularity::Channel, &info);
        let mask = Mask::full(22);
        let mut rng = Rng::new(2);
        for drc in [1, 4, 7, 22] {
            let s = sampler.sample(&mask, &mut rng, drc);
            assert_eq!(s.len(), drc, "drc={drc}");
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), drc, "duplicates at drc={drc}");
        }
        // A full-channel draw (drc = multiple of channel size) covers whole
        // channels: drc=8 on layer-0-only mask = exactly 2 channels.
        let mut l0_only = Mask::full(22);
        l0_only.remove_layer(&info, 1);
        let s = sampler.sample(&l0_only, &mut rng, 8);
        let mut chans: Vec<usize> = s.iter().map(|&i| i / 4).collect();
        chans.sort_unstable();
        chans.dedup();
        assert_eq!(chans.len(), 2, "expected exactly 2 whole channels: {s:?}");
    }

    #[test]
    fn channel_sampler_skips_empty_channels() {
        let info = two_layer_info();
        let sampler = BlockSampler::new(Granularity::Channel, &info);
        let mut mask = Mask::full(22);
        // Empty channel 0 of layer 0 (indices 0..4).
        for i in 0..4 {
            mask.remove(i).unwrap();
        }
        let mut rng = Rng::new(3);
        for _ in 0..30 {
            let s = sampler.sample(&mask, &mut rng, 6);
            assert!(s.iter().all(|&i| i >= 4), "sampled from empty channel: {s:?}");
        }
    }

    #[test]
    fn scan_state_claims_in_order_with_lower_index_floor() {
        // claim_slab(1) is exactly the old one-index claim.
        let mut st = ScanState { next: 0, stop_at: None, results: vec![None; 4] };
        assert_eq!(st.claim_slab(1), Some((0, 1, 0.0)));
        st.results[0] = Some(TrialEval::Scored { acc: 60.0, batch_corrects: vec![] });
        assert_eq!(st.claim_slab(1), Some((1, 1, 60.0)));
        st.results[1] = Some(TrialEval::Bounded); // bounded trials add no floor
        assert_eq!(st.claim_slab(1), Some((2, 1, 60.0)));
        st.stop_at = Some(2);
        assert_eq!(st.claim_slab(1), None, "no claims beyond the accept index");
    }

    #[test]
    fn replay_merge_matches_algorithm_2() {
        let hyps: Vec<MaskDelta> = (0..5).map(|i| MaskDelta::new(vec![i])).collect();
        let scored = |acc: f64| Some(TrialEval::Scored { acc, batch_corrects: vec![] });
        // base 80, adt 0.5: trial 3 accepts (dacc 0.2); trial 4 (unclaimed)
        // is never consulted; trial 1 is a runtime bound.
        let results = vec![scored(70.0), Some(TrialEval::Bounded), scored(75.0), scored(79.8), None];
        let out = replay_merge(&hyps, results, 80.0, 0.5, |_, _| false);
        assert_eq!(out.chosen, Trial { removed: vec![3], acc: 79.8, dacc: 80.0 - 79.8 });
        assert_eq!((out.evaluated, out.bounded), (4, 1));
        assert!(out.early_accept);
        // Merge-side bound: a predicate that cuts below the incumbent floor
        // turns lower-acc trials into bounds; the argmax is unchanged.
        let sc = |acc: f64| Some(TrialEval::Scored { acc, batch_corrects: vec![acc] });
        let results = vec![sc(70.0), sc(60.0), sc(75.0), None, None];
        let out = replay_merge(&hyps, results, 80.0, 0.5, |c, floor| c[0] < floor);
        assert_eq!(out.chosen.removed, vec![2]);
        assert_eq!((out.evaluated, out.bounded, out.early_accept), (3, 1, false));
    }

    #[test]
    fn scan_state_slab_claims_clamp_to_len_and_stop() {
        let mut st = ScanState { next: 0, stop_at: None, results: vec![None; 7] };
        // First slab: full width, floor 0 (nothing completed below it).
        assert_eq!(st.claim_slab(3), Some((0, 3, 0.0)));
        st.results[0] = Some(TrialEval::Scored { acc: 55.0, batch_corrects: vec![] });
        st.results[2] = Some(TrialEval::Scored { acc: 70.0, batch_corrects: vec![] });
        // Second slab: floor is the best COMPLETED accuracy below index 3,
        // even though index 1 is still outstanding.
        assert_eq!(st.claim_slab(3), Some((3, 3, 70.0)));
        // An accept at index 6 clamps the final slab to end at stop + 1.
        st.stop_at = Some(6);
        assert_eq!(st.claim_slab(3), Some((6, 1, 70.0)));
        assert_eq!(st.claim_slab(3), None, "nothing claimable past the accept");
    }
}
