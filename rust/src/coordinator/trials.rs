//! The random-trial scheduler inside one BCD iteration (Algorithm 2,
//! lines 7–20): sample DRC present ReLUs, score the hypothesis, early-accept
//! under ADT, otherwise keep the argmin-degradation candidate.

use crate::config::Granularity;
use crate::coordinator::eval::Evaluator;
use crate::model::Mask;
use crate::runtime::manifest::ModelInfo;
use crate::util::prng::Rng;
use anyhow::Result;
use std::collections::HashSet;

/// Draws one DRC-sized removal hypothesis at the configured granularity.
pub struct BlockSampler<'a> {
    granularity: Granularity,
    info: &'a ModelInfo,
}

impl<'a> BlockSampler<'a> {
    pub fn new(granularity: Granularity, info: &'a ModelInfo) -> BlockSampler<'a> {
        BlockSampler { granularity, info }
    }

    /// Sample exactly `drc` present ReLU indices to remove.
    pub fn sample(&self, mask: &Mask, rng: &mut Rng, drc: usize) -> Vec<usize> {
        match self.granularity {
            Granularity::Pixel => mask.sample_present(rng, drc),
            Granularity::Channel => self.sample_channels(mask, rng, drc),
        }
    }

    /// Channel blocks: draw whole channels (H*W consecutive flat indices)
    /// until `drc` ReLUs accumulate; the final channel is truncated to a
    /// random subset so the hypothesis removes exactly `drc` (keeping the
    /// exact-landing invariant of Algorithm 2).
    fn sample_channels(&self, mask: &Mask, rng: &mut Rng, drc: usize) -> Vec<usize> {
        // Channels that still hold present ReLUs, as (start, end) ranges.
        let mut channels: Vec<(usize, usize)> = Vec::new();
        for e in &self.info.mask_layers {
            let (c, hw) = (e.shape[0], e.size / e.shape[0]);
            for ci in 0..c {
                let start = e.offset + ci * hw;
                if (start..start + hw).any(|i| mask.is_present(i)) {
                    channels.push((start, start + hw));
                }
            }
        }
        rng.shuffle(&mut channels);
        let mut removed = Vec::with_capacity(drc);
        for (start, end) in channels {
            if removed.len() == drc {
                break;
            }
            let mut present: Vec<usize> =
                (start..end).filter(|&i| mask.is_present(i)).collect();
            let need = drc - removed.len();
            if present.len() > need {
                rng.shuffle(&mut present);
                present.truncate(need);
            }
            removed.extend(present);
        }
        assert_eq!(removed.len(), drc, "not enough present ReLUs for DRC={drc}");
        removed
    }
}

/// One scored mask hypothesis.
#[derive(Clone, Debug)]
pub struct Trial {
    /// Flat ReLU indices this hypothesis removes.
    pub removed: Vec<usize>,
    /// Proxy accuracy [%] with the hypothesis applied.
    pub acc: f64,
    /// Degradation vs. the iteration's base accuracy (percentage points).
    pub dacc: f64,
}

/// Result of one iteration's trial scan.
#[derive(Clone, Debug)]
pub struct ScanOutcome {
    pub chosen: Trial,
    /// Trials actually evaluated (<= RT; early-accept can cut it short).
    pub evaluated: usize,
    /// Trials aborted early by the accuracy bound (§Perf).
    pub bounded: usize,
    /// Whether the chosen trial passed the ADT early-accept test.
    pub early_accept: bool,
}

/// Scan up to `rt` random DRC-sized hypotheses of `mask` (never mutates it).
///
/// `base_acc` is the iteration's pre-removal proxy accuracy; `adt` the
/// Accuracy Degradation Tolerance in percentage points. Duplicate draws are
/// skipped without consuming a trial evaluation.
#[allow(clippy::too_many_arguments)]
pub fn scan_trials(
    ev: &Evaluator,
    params: &xla::PjRtBuffer,
    mask: &Mask,
    sampler: &BlockSampler,
    drc: usize,
    rt: usize,
    adt: f64,
    base_acc: f64,
    rng: &mut Rng,
) -> Result<ScanOutcome> {
    assert!(drc <= mask.count(), "DRC {drc} > present ReLUs {}", mask.count());
    let mut scratch: Vec<f32> = Vec::with_capacity(mask.size());
    let mut seen: HashSet<Vec<usize>> = HashSet::new();
    let mut best: Option<Trial> = None;
    let mut evaluated = 0usize;
    let mut bounded = 0usize;

    for _ in 0..rt {
        let mut removed = sampler.sample(mask, rng, drc);
        removed.sort_unstable();
        if !seen.insert(removed.clone()) {
            continue; // duplicate draw: re-sample without burning an eval
        }
        mask.hypothesis_into(&removed, &mut scratch);

        // Early-exit bound: the hypothesis only matters if it beats the
        // incumbent argmin accuracy.
        let floor = best.as_ref().map(|b| b.acc).unwrap_or(0.0);
        evaluated += 1;
        let acc = match ev.accuracy_bounded(params, &scratch, floor)? {
            Some(a) => a,
            None => {
                bounded += 1;
                continue;
            }
        };
        let dacc = base_acc - acc;
        let better = best.as_ref().map(|b| acc > b.acc).unwrap_or(true);
        if better {
            best = Some(Trial { removed, acc, dacc });
        }
        if dacc < adt {
            // Algorithm 2 line 11: accept immediately under the tolerance.
            return Ok(ScanOutcome {
                chosen: best.expect("just set"),
                evaluated,
                bounded,
                early_accept: true,
            });
        }
    }
    let chosen = best.expect("rt >= 1 and first trial always completes");
    Ok(ScanOutcome { chosen, evaluated, bounded, early_accept: false })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::{ModelInfo, PackEntry};

    fn two_layer_info() -> ModelInfo {
        // Layer 0: 4 channels of 2x2 (16); layer 1: 2 channels of 3x1 (6).
        ModelInfo {
            key: "t".into(),
            backbone: "resnet".into(),
            num_classes: 2,
            image_size: 4,
            channels: 3,
            poly: false,
            param_size: 1,
            mask_size: 22,
            mask_layers: vec![
                PackEntry { name: "a".into(), shape: vec![4, 2, 2], offset: 0, size: 16 },
                PackEntry { name: "b".into(), shape: vec![2, 3, 1], offset: 16, size: 6 },
            ],
            param_entries: vec![],
            artifacts: Default::default(),
        }
    }

    #[test]
    fn pixel_sampler_draws_present_only() {
        let info = two_layer_info();
        let sampler = BlockSampler::new(Granularity::Pixel, &info);
        let mut mask = Mask::full(22);
        mask.remove(0).unwrap();
        let mut rng = Rng::new(1);
        for _ in 0..50 {
            let s = sampler.sample(&mask, &mut rng, 5);
            assert_eq!(s.len(), 5);
            assert!(s.iter().all(|&i| mask.is_present(i)));
        }
    }

    #[test]
    fn channel_sampler_exact_count_and_block_structure() {
        let info = two_layer_info();
        let sampler = BlockSampler::new(Granularity::Channel, &info);
        let mask = Mask::full(22);
        let mut rng = Rng::new(2);
        for drc in [1, 4, 7, 22] {
            let s = sampler.sample(&mask, &mut rng, drc);
            assert_eq!(s.len(), drc, "drc={drc}");
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), drc, "duplicates at drc={drc}");
        }
        // A full-channel draw (drc = multiple of channel size) covers whole
        // channels: drc=8 on layer-0-only mask = exactly 2 channels.
        let mut l0_only = Mask::full(22);
        l0_only.remove_layer(&info, 1);
        let s = sampler.sample(&l0_only, &mut rng, 8);
        let mut chans: Vec<usize> = s.iter().map(|&i| i / 4).collect();
        chans.sort_unstable();
        chans.dedup();
        assert_eq!(chans.len(), 2, "expected exactly 2 whole channels: {s:?}");
    }

    #[test]
    fn channel_sampler_skips_empty_channels() {
        let info = two_layer_info();
        let sampler = BlockSampler::new(Granularity::Channel, &info);
        let mut mask = Mask::full(22);
        // Empty channel 0 of layer 0 (indices 0..4).
        for i in 0..4 {
            mask.remove(i).unwrap();
        }
        let mut rng = Rng::new(3);
        for _ in 0..30 {
            let s = sampler.sample(&mask, &mut rng, 6);
            assert!(s.iter().all(|&i| i >= 4), "sampled from empty channel: {s:?}");
        }
    }
}
