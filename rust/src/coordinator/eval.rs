//! Batched accuracy evaluation over a fixed batch set.
//!
//! The BCD inner loop evaluates O(T·RT) mask hypotheses; this is the L3 hot
//! path. Three optimizations live here (§Perf, measured in EXPERIMENTS.md):
//!
//! 1. **Device-buffer caching** — the evaluation batches and the current
//!    parameter vector are uploaded once per BCD iteration; each trial only
//!    uploads its (small) mask vector.
//! 2. **Early-exit bound** — while scanning trials for the argmin
//!    degradation, a trial is aborted as soon as even 100%-correct remaining
//!    batches could not beat the incumbent.
//! 3. **Staged execution** (DESIGN.md §8) — a hypothesis differs from the
//!    iteration's base mask at only DRC indices; when they all land past
//!    mask layer 0, the forward pass resumes from a cached base-mask
//!    boundary activation ([`Evaluator::eval_trial_delta`]) instead of
//!    re-running the whole network. The cache is per iteration, bounded by
//!    `bcd.cache_mb` with LRU eviction, and the incremental per-batch
//!    correct counts are **bit-identical** to full forwards (checked per
//!    batch in debug builds, and in release under `bcd.verify_staged`), so
//!    the replay-merge determinism contract of
//!    [`crate::coordinator::trials`] is untouched.
//! 4. **Batched multi-trial scoring** (DESIGN.md §11) — a slab of up to
//!    `bcd.trial_batch` hypotheses is scored per backend call
//!    ([`Evaluator::eval_trial_slab`]): hypotheses are grouped by route
//!    (same resume boundary, or full forwards), the group's masks go up as
//!    ONE slab upload, and the backend shares every mask-independent
//!    affine across the hypothesis axis. Per-hypothesis results and the
//!    early-exit bound arithmetic are bit-identical to the single-trial
//!    path, so `ScanOutcome`s do not depend on the slab width.
//!
//! **Partial-batch accounting.** Backends run a fixed batch shape, so the
//! final batch of a dataset that does not divide evenly is wrap-padded.
//! The evaluator tracks the *valid* prefix of every batch: padded examples
//! are excluded from the accuracy numerator (the padded tail of the last
//! batch is re-scored exactly through the `forward` entry point) and from
//! the denominator (`num_examples` is the true example count, not
//! `batches * batch`), so neither the accuracy nor the early-exit bound is
//! skewed.

use crate::data::Dataset;
use crate::model::{Mask, MaskDelta};
use crate::runtime::backend::{DeviceBuf, MaskSlab};
use crate::runtime::session::Session;
use crate::tensor::Tensor;
use anyhow::{anyhow, bail, Result};
use std::collections::{BTreeMap, HashMap};
use std::sync::{Arc, Mutex};

/// One cached evaluation batch: device buffers plus the host-side labels
/// needed to re-score a padded tail exactly.
struct EvalBatch {
    x: DeviceBuf,
    y: DeviceBuf,
    /// Host copy of the labels, kept ONLY for batches with a padded tail
    /// (`valid < batch`); full batches never consult them, so cloning
    /// labels for every batch would be pure waste.
    labels: Vec<i32>,
    /// How many leading examples are real (== batch except possibly last).
    valid: usize,
}

/// Per-iteration cache of base-mask boundary activations (§Perf opt 3).
///
/// Keyed by `(batch index, segment boundary)`; shared across scan workers
/// behind a mutex. Values are `Arc`s so a worker can keep using an
/// activation another worker just evicted.
struct PrefixCache {
    /// Byte budget for cached activations (the `bcd.cache_mb` knob).
    budget_bytes: usize,
    /// Segment boundaries the backend supports for this model.
    segments: usize,
    /// Size in bytes of one cached entry per boundary.
    entry_bytes: Vec<usize>,
    /// Deepest mask layer folded into each boundary's activation
    /// (`Backend::segment_layer`): boundary `b` serves a hypothesis whose
    /// first dirty layer is `> boundary_layers[b]`, and its mask suffix
    /// starts at layer `boundary_layers[b] + 1`.
    boundary_layers: Vec<usize>,
    inner: Mutex<PrefixInner>,
}

/// Prefix-cache event tallies. Tracked in ONE place (under the cache's own
/// mutex, which the hot path already holds) and mirrored into the backend
/// `StatsRecorder` once per scan by [`Evaluator::flush_cache_stats`] — not
/// per batch, which would add global-mutex traffic to the path this cache
/// exists to speed up.
#[derive(Clone, Copy, Default)]
struct CacheCounts {
    hits: u64,
    misses: u64,
    evictions: u64,
    staged_trials: u64,
}

#[derive(Default)]
struct PrefixInner {
    /// The iteration's base mask, uploaded by [`Evaluator::begin_iteration`].
    base: Option<Arc<DeviceBuf>>,
    map: HashMap<(usize, usize), Arc<DeviceBuf>>,
    /// LRU order, oldest first.
    order: Vec<(usize, usize)>,
    bytes: usize,
    counts: CacheCounts,
    /// Counter values already mirrored into the backend stats.
    flushed: CacheCounts,
}

impl PrefixCache {
    /// `None` when staging cannot help: zero budget, no backend support,
    /// or a budget too small to hold even one boundary activation.
    fn build(sess: &Session, batch: usize, budget_bytes: usize) -> Option<PrefixCache> {
        if budget_bytes == 0 {
            return None;
        }
        // A boundary whose deepest folded layer is at or past the last
        // mask layer can never be resumed from (no dirty layer lies
        // beyond it), so clamp whatever the backend reports to the layer
        // table. `Backend::segment_layer` is strictly increasing, so
        // trimming from the back is enough.
        let info = sess.info();
        let boundary_layers: Vec<usize> = (0..sess.segments())
            .map(|b| sess.backend.segment_layer(&sess.key, b))
            .collect();
        let mut segments = boundary_layers.len();
        while segments > 0 && boundary_layers[segments - 1] >= info.mask_layers.len().saturating_sub(1) {
            segments -= 1;
        }
        if segments == 0 {
            return None;
        }
        // Entry sizes come from the backend — it owns the handle layout
        // (`Backend::prefix_entry_bytes`; one f32 per mask-layer unit for
        // the reference MLP, a full feature map for conv boundaries).
        let entry_bytes: Vec<usize> = (0..segments)
            .map(|b| sess.backend.prefix_entry_bytes(&sess.key, b, batch))
            .collect();
        if entry_bytes.iter().all(|&e| e > budget_bytes) {
            return None;
        }
        Some(PrefixCache {
            budget_bytes,
            segments,
            entry_bytes,
            boundary_layers,
            inner: Mutex::new(PrefixInner::default()),
        })
    }

    fn has_base(&self) -> bool {
        self.inner.lock().unwrap().base.is_some()
    }
}

/// Batched-scoring event tallies (§Perf opt 4), mirrored into the backend
/// stats as `trial_batch:*` keys by [`Evaluator::flush_cache_stats`] —
/// same once-per-scan flushing discipline as [`CacheCounts`].
#[derive(Clone, Copy, Default)]
struct BatchCounts {
    /// Slab groups scored (each = one slab upload, satellite of ISSUE 6).
    slabs: u64,
    /// Hypotheses scored through batched *staged* (resume) calls.
    staged_trials: u64,
    /// Hypotheses scored through batched *full-forward* calls.
    full_trials: u64,
    /// Batched backend calls issued (`*_multi` entries).
    multi_calls: u64,
    /// Sum over batched calls of the live-hypothesis width — so
    /// `width_sum / multi_calls` is the realized mean batch width.
    width_sum: u64,
}

#[derive(Default)]
struct BatchTallies {
    counts: BatchCounts,
    /// Counter values already mirrored into the backend stats.
    flushed: BatchCounts,
}

/// Throughput/verification knobs of an [`Evaluator`] — all NON-semantic:
/// none of them may change any score bit (`bcd.cache_mb`,
/// `bcd.trial_batch`, `bcd.verify_staged`, `bcd.verify_lowering`).
#[derive(Clone, Copy, Debug)]
pub struct EvalOpts {
    /// Prefix-activation cache budget in bytes (0 disables staging).
    pub cache_bytes: usize,
    /// Hypothesis-slab width cap for batched scoring; clamped to the
    /// backend's `multi_width`. 1 scores every trial singly.
    pub trial_batch: usize,
    /// Check every staged/batched score against its own full forward in
    /// release builds too (debug builds always check).
    pub verify_staged: bool,
    /// Cross-check every lowered conv kernel call against the retained
    /// direct loop in release builds too (debug builds always check) —
    /// the DESIGN.md §13 analogue of `verify_staged`.
    pub verify_lowering: bool,
}

impl Default for EvalOpts {
    fn default() -> Self {
        EvalOpts {
            cache_bytes: 64 << 20,
            trial_batch: 1,
            verify_staged: false,
            verify_lowering: false,
        }
    }
}

/// Outcome of scoring one mask hypothesis against the batch set.
#[derive(Clone, Debug, PartialEq)]
pub enum TrialEval {
    /// The early-exit bound proved the trial cannot reach the floor.
    Bounded,
    /// Full evaluation: accuracy [%] plus the per-batch correct counts
    /// (valid examples only) — the replay data the deterministic parallel
    /// scan merge needs (see [`crate::coordinator::trials`]).
    Scored { acc: f64, batch_corrects: Vec<f64> },
}

/// A fixed, device-resident set of evaluation batches.
pub struct Evaluator<'e, 's> {
    sess: &'s Session<'e>,
    batches: Vec<EvalBatch>,
    batch: usize,
    examples: usize,
    /// Prefix-activation cache for staged trial scoring (None = disabled;
    /// every trial then runs full forwards).
    prefix: Option<PrefixCache>,
    /// Requested hypothesis-slab width (`bcd.trial_batch`); the effective
    /// width is [`Self::slab_width`].
    trial_batch: usize,
    /// Release-mode staged/batched-vs-full verification (`bcd.verify_staged`).
    verify_staged: bool,
    /// Batched-scoring tallies (flushed by [`Self::flush_cache_stats`]).
    tallies: Mutex<BatchTallies>,
}

impl<'e, 's> Evaluator<'e, 's> {
    /// Build from the first `max_batches` deterministic contiguous batches
    /// of `ds` (the paper evaluates trial ΔAcc on the *train* set; using a
    /// fixed subset keeps trial comparisons consistent). Staged execution
    /// is disabled; use [`Self::with_cache`] on the BCD hot path.
    pub fn new(
        sess: &'s Session<'e>,
        ds: &Dataset,
        max_batches: usize,
    ) -> Result<Evaluator<'e, 's>> {
        Self::with_cache(sess, ds, max_batches, 0)
    }

    /// [`Self::new`] plus a prefix-activation cache of `cache_mb` MiB — the
    /// `bcd.cache_mb` knob. `0` disables staging entirely (every trial runs
    /// full forwards); any positive budget lets trials whose [`MaskDelta`]
    /// leaves mask layer 0 untouched resume from cached base-mask
    /// activations, bit-identically (DESIGN.md §8).
    pub fn with_cache(
        sess: &'s Session<'e>,
        ds: &Dataset,
        max_batches: usize,
        cache_mb: usize,
    ) -> Result<Evaluator<'e, 's>> {
        Self::with_cache_bytes(sess, ds, max_batches, cache_mb.saturating_mul(1 << 20))
    }

    /// Byte-granular [`Self::with_cache`] (benches and eviction tests).
    pub fn with_cache_bytes(
        sess: &'s Session<'e>,
        ds: &Dataset,
        max_batches: usize,
        cache_bytes: usize,
    ) -> Result<Evaluator<'e, 's>> {
        Self::with_opts(sess, ds, max_batches, EvalOpts { cache_bytes, ..EvalOpts::default() })
    }

    /// Full-knob constructor: cache budget, hypothesis-slab width and
    /// staged/batched verification in one [`EvalOpts`] (how
    /// [`crate::coordinator::bcd::run_bcd`] wires `bcd.*` through).
    pub fn with_opts(
        sess: &'s Session<'e>,
        ds: &Dataset,
        max_batches: usize,
        opts: EvalOpts,
    ) -> Result<Evaluator<'e, 's>> {
        // The lowering cross-check is a process-wide kernel knob, not
        // per-evaluator state: arm it here so every conv call made on
        // behalf of this evaluator (any thread) is checked.
        crate::runtime::lowering::set_verify_lowering(opts.verify_lowering);
        let batch = sess.batch;
        let avail = ds.len().div_ceil(batch);
        let n = max_batches.min(avail).max(1);
        let mut batches = Vec::with_capacity(n);
        let mut examples = 0usize;
        for b in 0..n {
            let start = b * batch;
            let (x, y) = ds.batch_at(start, batch);
            let valid = batch.min(ds.len().saturating_sub(start)).max(1);
            // Host labels only matter for re-scoring a wrap-padded tail.
            let labels = if valid < batch { y.data.clone() } else { Vec::new() };
            let (xb, yb) = sess.upload_batch(&x, &y)?;
            examples += valid;
            batches.push(EvalBatch { x: xb, y: yb, labels, valid });
        }
        let prefix = PrefixCache::build(sess, batch, opts.cache_bytes);
        Ok(Evaluator {
            sess,
            batches,
            batch,
            examples,
            prefix,
            trial_batch: opts.trial_batch,
            verify_staged: opts.verify_staged,
            tallies: Mutex::new(BatchTallies::default()),
        })
    }

    /// Number of *real* examples this evaluator scores (padding excluded).
    pub fn num_examples(&self) -> usize {
        self.examples
    }

    pub fn num_batches(&self) -> usize {
        self.batches.len()
    }

    /// Upload a parameter vector for reuse across many [`Self::accuracy`]
    /// calls (one upload per BCD iteration, not per trial).
    pub fn upload_params(&self, params: &Tensor) -> Result<DeviceBuf> {
        self.sess.upload_f32(&params.data, &params.shape)
    }

    /// Upload a trial mask for reuse across the batch sweep (the one
    /// per-call upload of the hot path, shared by every scoring method).
    pub fn upload_mask(&self, mask: &[f32]) -> Result<DeviceBuf> {
        self.sess.upload_f32(mask, &[mask.len()])
    }

    /// Loss + valid-prefix correct count of one cached batch.
    fn score_batch(
        &self,
        b: &EvalBatch,
        params: &DeviceBuf,
        mask_buf: &DeviceBuf,
    ) -> Result<(f64, f64)> {
        if b.valid == self.batch {
            let out = self.sess.eval_batch_b(params, mask_buf, &b.x, &b.y)?;
            return Ok((out.loss as f64, out.correct as f64));
        }
        // Partial batch: the compiled eval_batch scalar includes the padded
        // tail, so re-score through forward and count the valid prefix only.
        let logits = self.sess.forward_b(params, mask_buf, &b.x)?;
        let correct = count_valid_correct(&logits, &b.labels, b.valid)?;
        let k = logits.shape[1];
        let mut loss = 0.0f64;
        for (i, &label) in b.labels.iter().take(b.valid).enumerate() {
            let row = &logits.data[i * k..(i + 1) * k];
            loss += cross_entropy(row, label as usize % k);
        }
        Ok((loss / b.valid as f64, correct))
    }

    /// Accuracy [%] of (params, mask) on the cached batches.
    pub fn accuracy(&self, params: &DeviceBuf, mask: &[f32]) -> Result<f64> {
        match self.eval_trial(params, mask, 0.0)? {
            TrialEval::Scored { acc, .. } => Ok(acc),
            TrialEval::Bounded => unreachable!("bound 0 never cuts"),
        }
    }

    /// Accuracy [%] with an early-exit bound: returns `None` as soon as the
    /// trial provably cannot reach `min_acc` [%] even if every remaining
    /// example were classified correctly.
    pub fn accuracy_bounded(
        &self,
        params: &DeviceBuf,
        mask: &[f32],
        min_acc: f64,
    ) -> Result<Option<f64>> {
        Ok(match self.eval_trial(params, mask, min_acc)? {
            TrialEval::Scored { acc, .. } => Some(acc),
            TrialEval::Bounded => None,
        })
    }

    /// Score one mask hypothesis with the early-exit bound, keeping the
    /// per-batch correct counts (the trial scan's replay data).
    pub fn eval_trial(
        &self,
        params: &DeviceBuf,
        mask: &[f32],
        min_acc: f64,
    ) -> Result<TrialEval> {
        let total = self.examples as f64;
        let need_correct = min_acc / 100.0 * total;
        let mask_buf = self.upload_mask(mask)?;
        let mut correct = 0.0f64;
        let mut remaining = total;
        let mut batch_corrects = Vec::with_capacity(self.batches.len());
        for b in &self.batches {
            let (_, c) = self.score_batch(b, params, &mask_buf)?;
            correct += c;
            remaining -= b.valid as f64;
            batch_corrects.push(c);
            if correct + remaining < need_correct {
                return Ok(TrialEval::Bounded); // cannot beat the incumbent
            }
        }
        Ok(TrialEval::Scored { acc: 100.0 * correct / total, batch_corrects })
    }

    /// Arm the prefix-activation cache for a new BCD iteration: upload
    /// `base` (the iteration's mask) and drop every cached activation from
    /// the previous iteration — both the parameters and the base mask have
    /// moved, so stale prefixes would be silently wrong. No-op when the
    /// cache is disabled.
    pub fn begin_iteration(&self, base: &Mask) -> Result<()> {
        let Some(pc) = &self.prefix else { return Ok(()) };
        let buf = Arc::new(self.sess.upload_f32(base.dense(), &[base.size()])?);
        let mut inner = pc.inner.lock().unwrap();
        inner.base = Some(buf);
        inner.map.clear();
        inner.order.clear();
        inner.bytes = 0;
        Ok(())
    }

    /// Whether trials can take the staged path (cache enabled AND the
    /// backend supports segmented forwards for this model).
    pub fn staged_enabled(&self) -> bool {
        self.prefix.is_some()
    }

    /// Cumulative prefix-cache counters `(hits, misses, evictions)`; zeros
    /// when the cache is disabled. [`Self::flush_cache_stats`] mirrors the
    /// same counts into the backend's stats table.
    pub fn cache_counters(&self) -> (u64, u64, u64) {
        match &self.prefix {
            Some(pc) => {
                let c = pc.inner.lock().unwrap().counts;
                (c.hits, c.misses, c.evictions)
            }
            None => (0, 0, 0),
        }
    }

    /// The effective hypothesis-slab width: the `bcd.trial_batch` request
    /// clamped to what the backend accepts (1 on PJRT).
    pub fn slab_width(&self) -> usize {
        self.trial_batch.min(self.sess.multi_width()).max(1)
    }

    /// Cumulative batched-scoring counters
    /// `(slabs, staged_trials, full_trials, multi_calls, width_sum)`.
    pub fn batch_counters(&self) -> (u64, u64, u64, u64, u64) {
        let c = self.tallies.lock().unwrap().counts;
        (c.slabs, c.staged_trials, c.full_trials, c.multi_calls, c.width_sum)
    }

    /// Mirror prefix-cache and batched-scoring counters accumulated since
    /// the last flush into the backend stats table (`prefix_cache:*` and
    /// `trial_batch:*` keys — shown by `cdnl runs show`). Called once per
    /// trial scan — the per-batch hot path only ever touches the cache's
    /// own mutex and the tallies mutex.
    pub fn flush_cache_stats(&self) {
        if let Some(pc) = &self.prefix {
            let d = {
                let mut inner = pc.inner.lock().unwrap();
                let d = CacheCounts {
                    hits: inner.counts.hits - inner.flushed.hits,
                    misses: inner.counts.misses - inner.flushed.misses,
                    evictions: inner.counts.evictions - inner.flushed.evictions,
                    staged_trials: inner.counts.staged_trials - inner.flushed.staged_trials,
                };
                inner.flushed = inner.counts;
                d
            };
            for (key, n) in [
                ("prefix_cache:hit", d.hits),
                ("prefix_cache:miss", d.misses),
                ("prefix_cache:evict", d.evictions),
                ("prefix_cache:staged_trials", d.staged_trials),
            ] {
                if n > 0 {
                    self.sess.backend.bump_stat(key, n);
                }
            }
        }
        let d = {
            let mut t = self.tallies.lock().unwrap();
            let d = BatchCounts {
                slabs: t.counts.slabs - t.flushed.slabs,
                staged_trials: t.counts.staged_trials - t.flushed.staged_trials,
                full_trials: t.counts.full_trials - t.flushed.full_trials,
                multi_calls: t.counts.multi_calls - t.flushed.multi_calls,
                width_sum: t.counts.width_sum - t.flushed.width_sum,
            };
            t.flushed = t.counts;
            d
        };
        for (key, n) in [
            ("trial_batch:slabs", d.slabs),
            ("trial_batch:trials_batched", d.staged_trials + d.full_trials),
            ("trial_batch:staged_trials", d.staged_trials),
            ("trial_batch:full_trials", d.full_trials),
            ("trial_batch:multi_calls", d.multi_calls),
            ("trial_batch:batch_width_sum", d.width_sum),
        ] {
            if n > 0 {
                self.sess.backend.bump_stat(key, n);
            }
        }
    }

    /// Score one hypothesis expressed as a sparse [`MaskDelta`] against the
    /// iteration's base mask. When the backend supports staged execution,
    /// the cache is armed ([`Self::begin_iteration`]) and the delta leaves
    /// mask layer 0 clean, each batch resumes from a cached boundary
    /// activation; otherwise this falls back to [`Self::eval_trial`]. The
    /// outcome is **bit-identical** either way — per-batch correct counts
    /// are checked against full forwards in debug builds, and in release
    /// builds under `bcd.verify_staged` (a mismatch is a hard error).
    ///
    /// `base` must be the mask handed to [`Self::begin_iteration`];
    /// `scratch` is the caller's dense-hypothesis buffer (no allocation on
    /// the hot path).
    pub fn eval_trial_delta(
        &self,
        params: &DeviceBuf,
        base: &Mask,
        delta: &MaskDelta,
        min_acc: f64,
        scratch: &mut Vec<f32>,
    ) -> Result<TrialEval> {
        base.hypothesis_into(delta.indices(), scratch);
        let dirty = delta.first_dirty_layer(self.sess.info());
        let Some((pc, boundary)) = self.staged_boundary(dirty) else {
            return self.eval_trial(params, scratch, min_acc);
        };
        let info = self.sess.info();
        let suffix_off = info.mask_layers[pc.boundary_layers[boundary] + 1].offset;
        let suffix_buf = self
            .sess
            .upload_f32(&scratch[suffix_off..], &[scratch.len() - suffix_off])?;
        // The incremental-vs-full determinism contract (DESIGN.md §8):
        // checked on every staged batch in debug builds, and in release
        // builds under `bcd.verify_staged`.
        let verify = self.verify_staged || cfg!(debug_assertions);
        let full_mask_buf = if verify { Some(self.upload_mask(scratch)?) } else { None };
        pc.inner.lock().unwrap().counts.staged_trials += 1;

        let total = self.examples as f64;
        let need_correct = min_acc / 100.0 * total;
        let mut correct = 0.0f64;
        let mut remaining = total;
        let mut batch_corrects = Vec::with_capacity(self.batches.len());
        for (bi, b) in self.batches.iter().enumerate() {
            let acts = self.prefix_acts(pc, bi, boundary, params, &b.x)?;
            let c = self.score_batch_from(b, boundary, &acts, params, &suffix_buf)?;
            if let Some(fb) = &full_mask_buf {
                let (_, full_c) = self.score_batch(b, params, fb)?;
                if c != full_c {
                    bail!(
                        "staged scoring diverged from full forward \
                         (batch {bi}: {c} vs {full_c})"
                    );
                }
            }
            correct += c;
            remaining -= b.valid as f64;
            batch_corrects.push(c);
            if correct + remaining < need_correct {
                return Ok(TrialEval::Bounded);
            }
        }
        Ok(TrialEval::Scored { acc: 100.0 * correct / total, batch_corrects })
    }

    /// The staged route for a delta whose first dirty layer is `dirty`:
    /// resume from the deepest boundary strictly before the first dirty
    /// layer (`boundary_layers[b] < dirty`) whose entry actually FITS the
    /// cache budget — an uncacheable boundary would recompute its prefix
    /// per trial, costing more than a full forward. A layer-0 delta, a
    /// disarmed cache, or no affordable boundary means full forwards
    /// (`None`).
    fn staged_boundary(&self, dirty: usize) -> Option<(&PrefixCache, usize)> {
        match &self.prefix {
            Some(pc) if dirty >= 1 && pc.has_base() => (0..pc.segments)
                .rev()
                .find(|&b| pc.boundary_layers[b] < dirty && pc.entry_bytes[b] <= pc.budget_bytes)
                .map(|b| (pc, b)),
            _ => None,
        }
    }

    /// Score a slab of hypotheses against the iteration's base mask,
    /// batching up to [`Self::slab_width`] of them per backend call
    /// (§Perf opt 4, DESIGN.md §11). Hypotheses are grouped by route —
    /// identical resume boundary, or full forwards — because only
    /// same-route hypotheses share their mask-independent affines; each
    /// group's masks are uploaded as ONE slab (the per-trial
    /// [`Self::upload_mask`] of the single path is hoisted to once per
    /// slab). Results are **bit-identical** to calling
    /// [`Self::eval_trial_delta`] per delta, including every `Bounded`
    /// decision: the bound arithmetic consumes the same per-batch floats in
    /// the same order.
    pub fn eval_trial_slab(
        &self,
        params: &DeviceBuf,
        base: &Mask,
        deltas: &[MaskDelta],
        min_acc: f64,
        scratch: &mut Vec<f32>,
    ) -> Result<Vec<TrialEval>> {
        let width = self.slab_width();
        if width <= 1 || deltas.len() <= 1 {
            return deltas
                .iter()
                .map(|d| self.eval_trial_delta(params, base, d, min_acc, scratch))
                .collect();
        }
        let info = self.sess.info();
        // Group by resume boundary (None = full forward). BTreeMap so the
        // grouping order is deterministic; results land by original index,
        // so ordering only affects backend-call scheduling anyway.
        let mut groups: BTreeMap<Option<usize>, Vec<usize>> = BTreeMap::new();
        for (i, d) in deltas.iter().enumerate() {
            let b = self.staged_boundary(d.first_dirty_layer(info)).map(|(_, b)| b);
            groups.entry(b).or_default().push(i);
        }
        let mut results: Vec<Option<TrialEval>> = vec![None; deltas.len()];
        for (boundary, idxs) in groups {
            for chunk in idxs.chunks(width) {
                if chunk.len() == 1 {
                    // A lone hypothesis gains nothing from the slab path.
                    results[chunk[0]] = Some(self.eval_trial_delta(
                        params,
                        base,
                        &deltas[chunk[0]],
                        min_acc,
                        scratch,
                    )?);
                    continue;
                }
                let slab: Vec<&MaskDelta> = chunk.iter().map(|&i| &deltas[i]).collect();
                let evals =
                    self.eval_slab_group(params, base, &slab, boundary, min_acc, scratch)?;
                for (&i, ev) in chunk.iter().zip(evals) {
                    results[i] = Some(ev);
                }
            }
        }
        Ok(results
            .into_iter()
            .map(|r| r.expect("every delta scored"))
            .collect())
    }

    /// Score one same-route slab group (all `deltas` resume from
    /// `boundary`, or all run full forwards). The early-exit bound runs
    /// per hypothesis with the exact float sequence of the single-trial
    /// path: `rem_after` is computed once per batch from the same
    /// subtraction [`Self::eval_trial`] performs, and each live hypothesis
    /// compares its own running `correct` against it.
    fn eval_slab_group(
        &self,
        params: &DeviceBuf,
        base: &Mask,
        deltas: &[&MaskDelta],
        boundary: Option<usize>,
        min_acc: f64,
        scratch: &mut Vec<f32>,
    ) -> Result<Vec<TrialEval>> {
        let n = deltas.len();
        let info = self.sess.info();
        let row_off = match boundary {
            Some(b) => {
                let pc = self.prefix.as_ref().expect("staged group without cache");
                info.mask_layers[pc.boundary_layers[b] + 1].offset
            }
            None => 0,
        };
        let verify = self.verify_staged || cfg!(debug_assertions);
        // ONE slab upload per group — the hoisted per-trial upload_mask.
        let mut rows: Vec<f32> = Vec::new();
        let mut verify_bufs: Vec<DeviceBuf> = Vec::new();
        let mut width = 0usize;
        for d in deltas {
            base.hypothesis_into(d.indices(), scratch);
            width = scratch.len() - row_off;
            rows.extend_from_slice(&scratch[row_off..]);
            if verify {
                verify_bufs.push(self.upload_mask(scratch)?);
            }
        }
        let slab = MaskSlab {
            buf: self.sess.upload_f32(&rows, &[n, width])?,
            n,
            width,
        };
        let pc_boundary = boundary.map(|b| {
            let pc = self.prefix.as_ref().expect("staged group without cache");
            (pc, b)
        });
        if let Some((pc, _)) = pc_boundary {
            pc.inner.lock().unwrap().counts.staged_trials += n as u64;
        }
        {
            let mut t = self.tallies.lock().unwrap();
            t.counts.slabs += 1;
            match boundary {
                Some(_) => t.counts.staged_trials += n as u64,
                None => t.counts.full_trials += n as u64,
            }
        }

        let total = self.examples as f64;
        let need_correct = min_acc / 100.0 * total;
        let mut live = vec![true; n];
        let mut corrects = vec![0.0f64; n];
        let mut batch_corrects: Vec<Vec<f64>> =
            (0..n).map(|_| Vec::with_capacity(self.batches.len())).collect();
        let mut results: Vec<Option<TrialEval>> = vec![None; n];
        let mut remaining = total;
        for (bi, b) in self.batches.iter().enumerate() {
            let cs = self.score_batch_multi(b, bi, params, &slab, pc_boundary, &live)?;
            // Same float op as the single path's `remaining -= valid`,
            // hoisted out of the hypothesis loop (it is mask-independent).
            let rem_after = remaining - b.valid as f64;
            for h in 0..n {
                if !live[h] {
                    continue;
                }
                let c = cs[h].ok_or_else(|| anyhow!("live hypothesis {h} not scored"))?;
                if verify {
                    let (_, full_c) = self.score_batch(b, params, &verify_bufs[h])?;
                    if c != full_c {
                        bail!(
                            "batched scoring diverged from full forward \
                             (batch {bi}, hypothesis {h}: {c} vs {full_c})"
                        );
                    }
                }
                corrects[h] += c;
                batch_corrects[h].push(c);
                if corrects[h] + rem_after < need_correct {
                    live[h] = false;
                    results[h] = Some(TrialEval::Bounded);
                }
            }
            remaining = rem_after;
            if live.iter().all(|&l| !l) {
                break; // every hypothesis bounded: skip the remaining batches
            }
        }
        Ok((0..n)
            .map(|h| {
                results[h].take().unwrap_or_else(|| TrialEval::Scored {
                    acc: 100.0 * corrects[h] / total,
                    batch_corrects: std::mem::take(&mut batch_corrects[h]),
                })
            })
            .collect())
    }

    /// Per-hypothesis valid-prefix correct counts of one cached batch for a
    /// mask slab — the batched twin of [`Self::score_batch`] /
    /// [`Self::score_batch_from`]. Dead (`!live`) hypotheses are skipped by
    /// the backend and come back `None`.
    fn score_batch_multi(
        &self,
        b: &EvalBatch,
        bi: usize,
        params: &DeviceBuf,
        slab: &MaskSlab,
        boundary: Option<(&PrefixCache, usize)>,
        live: &[bool],
    ) -> Result<Vec<Option<f64>>> {
        {
            let mut t = self.tallies.lock().unwrap();
            t.counts.multi_calls += 1;
            t.counts.width_sum += live.iter().filter(|&&l| l).count() as u64;
        }
        match boundary {
            Some((pc, seg)) => {
                let acts = self.prefix_acts(pc, bi, seg, params, &b.x)?;
                if b.valid == self.batch {
                    let outs = self.sess.eval_from_multi_b(seg, &acts, params, slab, &b.y, live)?;
                    Ok(outs.into_iter().map(|o| o.map(|s| s.correct as f64)).collect())
                } else {
                    let logits = self.sess.forward_from_multi_b(seg, &acts, params, slab, live)?;
                    logits
                        .into_iter()
                        .map(|o| {
                            o.map(|l| count_valid_correct(&l, &b.labels, b.valid)).transpose()
                        })
                        .collect()
                }
            }
            None => {
                if b.valid == self.batch {
                    let outs = self.sess.eval_batch_multi_b(params, slab, &b.x, &b.y, live)?;
                    Ok(outs.into_iter().map(|o| o.map(|s| s.correct as f64)).collect())
                } else {
                    let logits = self.sess.forward_multi_b(params, slab, &b.x, live)?;
                    logits
                        .into_iter()
                        .map(|o| {
                            o.map(|l| count_valid_correct(&l, &b.labels, b.valid)).transpose()
                        })
                        .collect()
                }
            }
        }
    }

    /// Fetch (or compute and cache) the base-mask activations of batch `bi`
    /// at `boundary`. Concurrent workers may duplicate a miss; the results
    /// are bit-identical, so last-writer-wins insertion is safe.
    fn prefix_acts(
        &self,
        pc: &PrefixCache,
        bi: usize,
        boundary: usize,
        params: &DeviceBuf,
        x: &DeviceBuf,
    ) -> Result<Arc<DeviceBuf>> {
        let key = (bi, boundary);
        let base = {
            let mut inner = pc.inner.lock().unwrap();
            if let Some(a) = inner.map.get(&key).cloned() {
                inner.counts.hits += 1;
                if let Some(p) = inner.order.iter().position(|&k| k == key) {
                    inner.order.remove(p);
                    inner.order.push(key);
                }
                return Ok(a);
            }
            inner
                .base
                .clone()
                .ok_or_else(|| anyhow!("prefix cache: begin_iteration not called"))?
        };
        // Miss: compute outside the lock.
        let acts = Arc::new(self.sess.forward_prefix_b(boundary, params, &base, x)?);
        let entry = pc.entry_bytes[boundary];
        let mut inner = pc.inner.lock().unwrap();
        inner.counts.misses += 1;
        if entry <= pc.budget_bytes && !inner.map.contains_key(&key) {
            inner.map.insert(key, acts.clone());
            inner.order.push(key);
            inner.bytes += entry;
            // LRU eviction down to budget; the entry just inserted is at
            // the back and is never the one evicted.
            while inner.bytes > pc.budget_bytes && inner.order.len() > 1 {
                let old = inner.order.remove(0);
                if inner.map.remove(&old).is_some() {
                    inner.bytes -= pc.entry_bytes[old.1];
                    inner.counts.evictions += 1;
                }
            }
        }
        drop(inner);
        Ok(acts)
    }

    /// Valid-prefix correct count of one cached batch, resumed from a
    /// cached boundary activation (the staged twin of [`Self::score_batch`]
    /// — the trial loop never needs the loss).
    fn score_batch_from(
        &self,
        b: &EvalBatch,
        boundary: usize,
        acts: &DeviceBuf,
        params: &DeviceBuf,
        suffix: &DeviceBuf,
    ) -> Result<f64> {
        if b.valid == self.batch {
            let out = self.sess.eval_from_b(boundary, acts, params, suffix, &b.y)?;
            return Ok(out.correct as f64);
        }
        // Padded tail: resume to logits and count the valid prefix through
        // the same helper as the full path — the bit-identity of the two
        // tail rescorings is structural, not duplicated.
        let logits = self.sess.forward_from_b(boundary, acts, params, suffix)?;
        count_valid_correct(&logits, &b.labels, b.valid)
    }

    /// Replay the early-exit bound decision on recorded per-batch correct
    /// counts: would a sequential evaluation against `min_acc` have cut this
    /// trial? Uses the exact arithmetic of [`Self::eval_trial`], so the
    /// parallel scan's merge is bit-identical to a sequential scan.
    pub fn would_bound(&self, batch_corrects: &[f64], min_acc: f64) -> bool {
        let total = self.examples as f64;
        let need_correct = min_acc / 100.0 * total;
        let mut correct = 0.0f64;
        let mut remaining = total;
        for (b, &c) in self.batches.iter().zip(batch_corrects) {
            correct += c;
            remaining -= b.valid as f64;
            if correct + remaining < need_correct {
                return true;
            }
        }
        false
    }

    /// Mean loss + accuracy [%] (used for reporting, not the trial loop).
    /// The loss is the example-weighted mean, exact under partial batches.
    pub fn loss_accuracy(&self, params: &DeviceBuf, mask: &[f32]) -> Result<(f64, f64)> {
        let mask_buf = self.upload_mask(mask)?;
        let (mut correct, mut loss) = (0.0f64, 0.0f64);
        for b in &self.batches {
            let (l, c) = self.score_batch(b, params, &mask_buf)?;
            correct += c;
            loss += l * b.valid as f64;
        }
        Ok((loss / self.examples as f64, 100.0 * correct / self.examples as f64))
    }
}

/// Valid-prefix correct count from logits — the padded-tail rescoring
/// shared by the full ([`Evaluator::score_batch`]) and staged
/// ([`Evaluator::score_batch_from`]) paths, so their agreement is by
/// construction rather than by parallel maintenance.
fn count_valid_correct(logits: &Tensor, labels: &[i32], valid: usize) -> Result<f64> {
    let preds = logits.argmax_rows()?;
    let mut correct = 0.0f64;
    for (i, &label) in labels.iter().take(valid).enumerate() {
        if preds[i] == label as usize {
            correct += 1.0;
        }
    }
    Ok(correct)
}

/// Host-side cross-entropy of one logit row (partial-batch rescoring).
fn cross_entropy(row: &[f32], target: usize) -> f64 {
    let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    let denom: f64 = row.iter().map(|&v| (v as f64 - max).exp()).sum();
    -(((row[target] as f64 - max).exp() / denom).max(1e-12)).ln()
}

/// One-shot test-set accuracy [%] for a model state (builds a throwaway
/// evaluator over the whole dataset).
pub fn test_accuracy(sess: &Session, st: &crate::model::ModelState, ds: &Dataset) -> Result<f64> {
    let ev = Evaluator::new(sess, ds, usize::MAX)?;
    let params = ev.upload_params(&st.params)?;
    ev.accuracy(&params, st.mask.dense())
}
