//! Batched accuracy evaluation over a fixed batch set.
//!
//! The BCD inner loop evaluates O(T·RT) mask hypotheses; this is the L3 hot
//! path. Two optimizations live here (§Perf, measured in EXPERIMENTS.md):
//!
//! 1. **Device-buffer caching** — the evaluation batches and the current
//!    parameter vector are uploaded once per BCD iteration; each trial only
//!    uploads its (small) mask vector.
//! 2. **Early-exit bound** — while scanning trials for the argmin
//!    degradation, a trial is aborted as soon as even 100%-correct remaining
//!    batches could not beat the incumbent.

use crate::data::Dataset;
use crate::runtime::session::Session;
use crate::tensor::Tensor;
use anyhow::Result;

/// A fixed, device-resident set of evaluation batches.
pub struct Evaluator<'e, 's> {
    sess: &'s Session<'e>,
    batches: Vec<(xla::PjRtBuffer, xla::PjRtBuffer)>,
    batch: usize,
}

impl<'e, 's> Evaluator<'e, 's> {
    /// Build from the first `max_batches` deterministic contiguous batches
    /// of `ds` (the paper evaluates trial ΔAcc on the *train* set; using a
    /// fixed subset keeps trial comparisons consistent).
    pub fn new(
        sess: &'s Session<'e>,
        ds: &Dataset,
        max_batches: usize,
    ) -> Result<Evaluator<'e, 's>> {
        let batch = sess.batch;
        let avail = ds.len().div_ceil(batch);
        let n = max_batches.min(avail).max(1);
        let mut batches = Vec::with_capacity(n);
        for b in 0..n {
            let (x, y) = ds.batch_at(b * batch, batch);
            batches.push(sess.upload_batch(&x, &y)?);
        }
        Ok(Evaluator { sess, batches, batch })
    }

    /// Number of examples this evaluator scores.
    pub fn num_examples(&self) -> usize {
        self.batches.len() * self.batch
    }

    pub fn num_batches(&self) -> usize {
        self.batches.len()
    }

    /// Upload a parameter vector for reuse across many [`Self::accuracy`]
    /// calls (one upload per BCD iteration, not per trial).
    pub fn upload_params(&self, params: &Tensor) -> Result<xla::PjRtBuffer> {
        self.sess.engine.upload_f32(&params.data, &params.shape)
    }

    /// Accuracy [%] of (params, mask) on the cached batches.
    pub fn accuracy(&self, params: &xla::PjRtBuffer, mask: &[f32]) -> Result<f64> {
        Ok(self.accuracy_bounded(params, mask, 0.0)?.expect("bound 0 never cuts"))
    }

    /// Accuracy [%] with an early-exit bound: returns `None` as soon as the
    /// trial provably cannot reach `min_acc` [%] even if every remaining
    /// example were classified correctly.
    pub fn accuracy_bounded(
        &self,
        params: &xla::PjRtBuffer,
        mask: &[f32],
        min_acc: f64,
    ) -> Result<Option<f64>> {
        let total = self.num_examples() as f64;
        let need_correct = min_acc / 100.0 * total;
        let mask_buf = self.sess.upload_f32(mask, &[mask.len()])?;
        let mut correct = 0.0f64;
        for (i, (x, y)) in self.batches.iter().enumerate() {
            let out = self.sess.eval_batch_b(params, &mask_buf, x, y)?;
            correct += out.correct as f64;
            let remaining = (self.batches.len() - 1 - i) as f64 * self.batch as f64;
            if correct + remaining < need_correct {
                return Ok(None); // cannot beat the incumbent
            }
        }
        Ok(Some(100.0 * correct / total))
    }

    /// Mean loss + accuracy [%] (used for reporting, not the trial loop).
    pub fn loss_accuracy(&self, params: &xla::PjRtBuffer, mask: &[f32]) -> Result<(f64, f64)> {
        let mask_buf = self.sess.upload_f32(mask, &[mask.len()])?;
        let (mut correct, mut loss) = (0.0f64, 0.0f64);
        for (x, y) in &self.batches {
            let out = self.sess.eval_batch_b(params, &mask_buf, x, y)?;
            correct += out.correct as f64;
            loss += out.loss as f64;
        }
        Ok((
            loss / self.batches.len() as f64,
            100.0 * correct / self.num_examples() as f64,
        ))
    }
}

/// One-shot test-set accuracy [%] for a model state (builds a throwaway
/// evaluator over the whole dataset).
pub fn test_accuracy(sess: &Session, st: &crate::model::ModelState, ds: &Dataset) -> Result<f64> {
    let ev = Evaluator::new(sess, ds, usize::MAX)?;
    let params = ev.upload_params(&st.params)?;
    ev.accuracy(&params, st.mask.dense())
}
