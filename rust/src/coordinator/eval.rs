//! Batched accuracy evaluation over a fixed batch set.
//!
//! The BCD inner loop evaluates O(T·RT) mask hypotheses; this is the L3 hot
//! path. Two optimizations live here (§Perf, measured in EXPERIMENTS.md):
//!
//! 1. **Device-buffer caching** — the evaluation batches and the current
//!    parameter vector are uploaded once per BCD iteration; each trial only
//!    uploads its (small) mask vector.
//! 2. **Early-exit bound** — while scanning trials for the argmin
//!    degradation, a trial is aborted as soon as even 100%-correct remaining
//!    batches could not beat the incumbent.
//!
//! **Partial-batch accounting.** Backends run a fixed batch shape, so the
//! final batch of a dataset that does not divide evenly is wrap-padded.
//! The evaluator tracks the *valid* prefix of every batch: padded examples
//! are excluded from the accuracy numerator (the padded tail of the last
//! batch is re-scored exactly through the `forward` entry point) and from
//! the denominator (`num_examples` is the true example count, not
//! `batches * batch`), so neither the accuracy nor the early-exit bound is
//! skewed.

use crate::data::Dataset;
use crate::runtime::backend::DeviceBuf;
use crate::runtime::session::Session;
use crate::tensor::Tensor;
use anyhow::Result;

/// One cached evaluation batch: device buffers plus the host-side labels
/// needed to re-score a padded tail exactly.
struct EvalBatch {
    x: DeviceBuf,
    y: DeviceBuf,
    /// Host copy of the labels (only consulted for partial batches).
    labels: Vec<i32>,
    /// How many leading examples are real (== batch except possibly last).
    valid: usize,
}

/// Outcome of scoring one mask hypothesis against the batch set.
#[derive(Clone, Debug, PartialEq)]
pub enum TrialEval {
    /// The early-exit bound proved the trial cannot reach the floor.
    Bounded,
    /// Full evaluation: accuracy [%] plus the per-batch correct counts
    /// (valid examples only) — the replay data the deterministic parallel
    /// scan merge needs (see [`crate::coordinator::trials`]).
    Scored { acc: f64, batch_corrects: Vec<f64> },
}

/// A fixed, device-resident set of evaluation batches.
pub struct Evaluator<'e, 's> {
    sess: &'s Session<'e>,
    batches: Vec<EvalBatch>,
    batch: usize,
    examples: usize,
}

impl<'e, 's> Evaluator<'e, 's> {
    /// Build from the first `max_batches` deterministic contiguous batches
    /// of `ds` (the paper evaluates trial ΔAcc on the *train* set; using a
    /// fixed subset keeps trial comparisons consistent).
    pub fn new(
        sess: &'s Session<'e>,
        ds: &Dataset,
        max_batches: usize,
    ) -> Result<Evaluator<'e, 's>> {
        let batch = sess.batch;
        let avail = ds.len().div_ceil(batch);
        let n = max_batches.min(avail).max(1);
        let mut batches = Vec::with_capacity(n);
        let mut examples = 0usize;
        for b in 0..n {
            let start = b * batch;
            let (x, y) = ds.batch_at(start, batch);
            let valid = batch.min(ds.len().saturating_sub(start)).max(1);
            let labels = y.data.clone();
            let (xb, yb) = sess.upload_batch(&x, &y)?;
            examples += valid;
            batches.push(EvalBatch { x: xb, y: yb, labels, valid });
        }
        Ok(Evaluator { sess, batches, batch, examples })
    }

    /// Number of *real* examples this evaluator scores (padding excluded).
    pub fn num_examples(&self) -> usize {
        self.examples
    }

    pub fn num_batches(&self) -> usize {
        self.batches.len()
    }

    /// Upload a parameter vector for reuse across many [`Self::accuracy`]
    /// calls (one upload per BCD iteration, not per trial).
    pub fn upload_params(&self, params: &Tensor) -> Result<DeviceBuf> {
        self.sess.upload_f32(&params.data, &params.shape)
    }

    /// Upload a trial mask for reuse across the batch sweep (the one
    /// per-call upload of the hot path, shared by every scoring method).
    pub fn upload_mask(&self, mask: &[f32]) -> Result<DeviceBuf> {
        self.sess.upload_f32(mask, &[mask.len()])
    }

    /// Loss + valid-prefix correct count of one cached batch.
    fn score_batch(
        &self,
        b: &EvalBatch,
        params: &DeviceBuf,
        mask_buf: &DeviceBuf,
    ) -> Result<(f64, f64)> {
        if b.valid == self.batch {
            let out = self.sess.eval_batch_b(params, mask_buf, &b.x, &b.y)?;
            return Ok((out.loss as f64, out.correct as f64));
        }
        // Partial batch: the compiled eval_batch scalar includes the padded
        // tail, so re-score through forward and count the valid prefix only.
        let logits = self.sess.forward_b(params, mask_buf, &b.x)?;
        let k = logits.shape[1];
        let preds = logits.argmax_rows()?;
        let mut correct = 0.0f64;
        let mut loss = 0.0f64;
        for (i, &label) in b.labels.iter().take(b.valid).enumerate() {
            if preds[i] == label as usize {
                correct += 1.0;
            }
            let row = &logits.data[i * k..(i + 1) * k];
            loss += cross_entropy(row, label as usize % k);
        }
        Ok((loss / b.valid as f64, correct))
    }

    /// Accuracy [%] of (params, mask) on the cached batches.
    pub fn accuracy(&self, params: &DeviceBuf, mask: &[f32]) -> Result<f64> {
        match self.eval_trial(params, mask, 0.0)? {
            TrialEval::Scored { acc, .. } => Ok(acc),
            TrialEval::Bounded => unreachable!("bound 0 never cuts"),
        }
    }

    /// Accuracy [%] with an early-exit bound: returns `None` as soon as the
    /// trial provably cannot reach `min_acc` [%] even if every remaining
    /// example were classified correctly.
    pub fn accuracy_bounded(
        &self,
        params: &DeviceBuf,
        mask: &[f32],
        min_acc: f64,
    ) -> Result<Option<f64>> {
        Ok(match self.eval_trial(params, mask, min_acc)? {
            TrialEval::Scored { acc, .. } => Some(acc),
            TrialEval::Bounded => None,
        })
    }

    /// Score one mask hypothesis with the early-exit bound, keeping the
    /// per-batch correct counts (the trial scan's replay data).
    pub fn eval_trial(
        &self,
        params: &DeviceBuf,
        mask: &[f32],
        min_acc: f64,
    ) -> Result<TrialEval> {
        let total = self.examples as f64;
        let need_correct = min_acc / 100.0 * total;
        let mask_buf = self.upload_mask(mask)?;
        let mut correct = 0.0f64;
        let mut remaining = total;
        let mut batch_corrects = Vec::with_capacity(self.batches.len());
        for b in &self.batches {
            let (_, c) = self.score_batch(b, params, &mask_buf)?;
            correct += c;
            remaining -= b.valid as f64;
            batch_corrects.push(c);
            if correct + remaining < need_correct {
                return Ok(TrialEval::Bounded); // cannot beat the incumbent
            }
        }
        Ok(TrialEval::Scored { acc: 100.0 * correct / total, batch_corrects })
    }

    /// Replay the early-exit bound decision on recorded per-batch correct
    /// counts: would a sequential evaluation against `min_acc` have cut this
    /// trial? Uses the exact arithmetic of [`Self::eval_trial`], so the
    /// parallel scan's merge is bit-identical to a sequential scan.
    pub fn would_bound(&self, batch_corrects: &[f64], min_acc: f64) -> bool {
        let total = self.examples as f64;
        let need_correct = min_acc / 100.0 * total;
        let mut correct = 0.0f64;
        let mut remaining = total;
        for (b, &c) in self.batches.iter().zip(batch_corrects) {
            correct += c;
            remaining -= b.valid as f64;
            if correct + remaining < need_correct {
                return true;
            }
        }
        false
    }

    /// Mean loss + accuracy [%] (used for reporting, not the trial loop).
    /// The loss is the example-weighted mean, exact under partial batches.
    pub fn loss_accuracy(&self, params: &DeviceBuf, mask: &[f32]) -> Result<(f64, f64)> {
        let mask_buf = self.upload_mask(mask)?;
        let (mut correct, mut loss) = (0.0f64, 0.0f64);
        for b in &self.batches {
            let (l, c) = self.score_batch(b, params, &mask_buf)?;
            correct += c;
            loss += l * b.valid as f64;
        }
        Ok((loss / self.examples as f64, 100.0 * correct / self.examples as f64))
    }
}

/// Host-side cross-entropy of one logit row (partial-batch rescoring).
fn cross_entropy(row: &[f32], target: usize) -> f64 {
    let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    let denom: f64 = row.iter().map(|&v| (v as f64 - max).exp()).sum();
    -(((row[target] as f64 - max).exp() / denom).max(1e-12)).ln()
}

/// One-shot test-set accuracy [%] for a model state (builds a throwaway
/// evaluator over the whole dataset).
pub fn test_accuracy(sess: &Session, st: &crate::model::ModelState, ds: &Dataset) -> Result<f64> {
    let ev = Evaluator::new(sess, ds, usize::MAX)?;
    let params = ev.upload_params(&st.params)?;
    ev.accuracy(&params, st.mask.dense())
}
