//! The L3 coordinator — the paper's system contribution.
//!
//! - [`bcd`] — Block Coordinate Descent over binary ReLU masks
//!   (Algorithm 2), the paper's optimizer, with per-sweep checkpoint hooks
//!   feeding the run-store ([`crate::runstore`]).
//! - [`trials`] — the random-trial scheduler inside one BCD iteration
//!   (sampling, dedup, early-accept, argmin fallback), fanned out across a
//!   worker pool with a deterministic replay merge.
//! - [`eval`] — batched accuracy evaluation with device-buffer caching,
//!   an early-exit bound, and exact partial-batch accounting (§Perf).
//! - [`finetune`] — cosine-annealed SGD finetune controller (L3 owns the
//!   schedule; L2 computes one step per call).
//! - [`train`] — the baseline full-ReLU training loop.

pub mod bcd;
pub mod eval;
pub mod finetune;
pub mod train;
pub mod trials;

pub use bcd::{run_bcd, run_bcd_resumable, BcdOutcome};
pub use eval::Evaluator;
