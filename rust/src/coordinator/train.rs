//! Baseline training loop: full-ReLU network, SGD-momentum with linear
//! warmup + cosine decay. Produces the reference models every method
//! (BCD, SNL, AutoReP, SENet, DeepReDuce) starts from.

use crate::config::TrainConfig;
use crate::coordinator::finetune::cosine_lr;
use crate::data::{Batcher, Dataset};
use crate::model::ModelState;
use crate::runtime::session::Session;
use crate::util::prng::Rng;
use anyhow::Result;

/// Per-training-run summary.
#[derive(Clone, Debug, Default)]
pub struct TrainStats {
    pub steps: usize,
    pub losses: Vec<f32>,
    pub final_train_acc: f64,
}

/// Warmup-then-cosine learning rate.
pub fn warmup_cosine_lr(lr0: f32, step: usize, warmup: usize, total: usize) -> f32 {
    if step < warmup {
        lr0 * (step + 1) as f32 / warmup.max(1) as f32
    } else {
        cosine_lr(lr0, step - warmup, total.saturating_sub(warmup).max(1))
    }
}

/// Train `st` in place for `cfg.steps` steps on `ds`.
pub fn train(
    sess: &Session,
    st: &mut ModelState,
    ds: &Dataset,
    cfg: &TrainConfig,
) -> Result<TrainStats> {
    let mut rng = Rng::new(cfg.seed);
    let mut batcher = Batcher::new(ds, sess.batch, &mut rng);
    let mut stats = TrainStats { steps: cfg.steps, ..Default::default() };
    let window = 20.min(cfg.steps.max(1));
    let mut recent_correct = std::collections::VecDeque::with_capacity(window);
    for step in 0..cfg.steps {
        let (x, y) = batcher.next_batch(&mut rng);
        let lr = warmup_cosine_lr(cfg.lr, step, cfg.warmup_steps, cfg.steps);
        let out = sess.train_step(st, &x, &y, lr)?;
        stats.losses.push(out.loss);
        if recent_correct.len() == window {
            recent_correct.pop_front();
        }
        recent_correct.push_back(out.correct as f64);
        if step % 50 == 0 || step + 1 == cfg.steps {
            crate::info!(
                "train step {step}/{}: loss={:.4} lr={lr:.4}",
                cfg.steps,
                out.loss
            );
        }
    }
    stats.final_train_acc = 100.0 * recent_correct.iter().sum::<f64>()
        / (recent_correct.len() * sess.batch).max(1) as f64;
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_ramps_then_decays() {
        let lr0 = 1.0;
        assert!(warmup_cosine_lr(lr0, 0, 10, 100) < 0.2);
        assert!((warmup_cosine_lr(lr0, 9, 10, 100) - 1.0).abs() < 1e-6);
        assert!(warmup_cosine_lr(lr0, 99, 10, 100) < 0.01);
    }

    #[test]
    fn no_warmup_is_pure_cosine() {
        assert!((warmup_cosine_lr(0.5, 0, 0, 50) - 0.5).abs() < 1e-6);
    }
}
