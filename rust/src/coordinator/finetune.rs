//! Finetune controller: cosine-annealed SGD (Loshchilov & Hutter 2016).
//!
//! The paper finetunes after every BCD reduction with SGD + cosine
//! annealing. L3 owns the schedule — the learning rate is computed here and
//! fed to the compiled `train_step` as a scalar input.

use crate::data::{Batcher, Dataset};
use crate::model::ModelState;
use crate::runtime::session::Session;
use crate::util::prng::Rng;
use anyhow::Result;

/// Cosine-annealed learning rate over `total` steps.
pub fn cosine_lr(lr0: f32, step: usize, total: usize) -> f32 {
    if total <= 1 {
        return lr0;
    }
    let t = step as f32 / (total - 1) as f32;
    lr0 * 0.5 * (1.0 + (std::f32::consts::PI * t).cos())
}

/// Summary of one finetune run.
#[derive(Clone, Copy, Debug, Default)]
pub struct FinetuneStats {
    pub steps: usize,
    pub first_loss: f32,
    pub last_loss: f32,
    pub mean_acc: f64,
}

/// Run `steps` SGD steps with a fresh cosine schedule, updating `st`.
pub fn finetune(
    sess: &Session,
    st: &mut ModelState,
    ds: &Dataset,
    steps: usize,
    lr0: f32,
    rng: &mut Rng,
) -> Result<FinetuneStats> {
    if steps == 0 {
        return Ok(FinetuneStats::default());
    }
    st.reset_momentum(); // paper restarts the schedule per finetune run
    let mut batcher = Batcher::new(ds, sess.batch, rng);
    let mut stats = FinetuneStats { steps, ..Default::default() };
    let mut correct_sum = 0.0f64;
    for step in 0..steps {
        let (x, y) = batcher.next_batch(rng);
        let lr = cosine_lr(lr0, step, steps);
        let out = sess.train_step(st, &x, &y, lr)?;
        if step == 0 {
            stats.first_loss = out.loss;
        }
        stats.last_loss = out.loss;
        correct_sum += out.correct as f64;
    }
    stats.mean_acc = 100.0 * correct_sum / (steps * sess.batch) as f64;
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cosine_endpoints() {
        assert!((cosine_lr(1.0, 0, 100) - 1.0).abs() < 1e-6);
        assert!(cosine_lr(1.0, 99, 100) < 1e-6);
        // midpoint = lr0 / 2
        let mid = cosine_lr(2.0, 50, 101);
        assert!((mid - 1.0).abs() < 1e-3, "mid {mid}");
    }

    #[test]
    fn cosine_monotone_decreasing() {
        let mut prev = f32::MAX;
        for s in 0..50 {
            let lr = cosine_lr(0.1, s, 50);
            assert!(lr <= prev + 1e-9, "step {s}: {lr} > {prev}");
            prev = lr;
        }
    }

    #[test]
    fn degenerate_single_step() {
        assert_eq!(cosine_lr(0.5, 0, 1), 0.5);
        assert_eq!(cosine_lr(0.5, 0, 0), 0.5);
    }
}
