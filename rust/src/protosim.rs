//! Deprecated shim over [`crate::pi::trace`] (kept so pre-PR-9 callers
//! compile).
//!
//! The DELPHI-style protocol walk lives in [`crate::pi::trace`] now,
//! where it shares its step [`script`](crate::pi::trace::script) with
//! the fleet-scale serving simulator ([`crate::pi::serve`]). This module
//! re-exports the types at their old paths and wraps the old free
//! functions with deprecation notes; new code should import from
//! `crate::pi`.

pub use crate::pi::trace::{Dir, Message, Trace};

use crate::model::Mask;
use crate::pi::Protocol;
use crate::runtime::manifest::ModelInfo;

#[deprecated(note = "use crate::pi::simulate")]
pub fn simulate(info: &ModelInfo, mask: &Mask, proto: &Protocol) -> Trace {
    crate::pi::simulate(info, mask, proto)
}

#[deprecated(note = "use crate::pi::compare (or the pi::CostModel trait)")]
pub fn compare(info: &ModelInfo, mask: &Mask, proto: &Protocol) -> (f64, f64) {
    crate::pi::compare(info, mask, proto)
}

#[cfg(test)]
mod tests {
    // The PR 9 compatibility contract: every pre-PR-9 call shape still
    // compiles and routes to the pi::trace implementation.
    #![allow(deprecated)]
    use super::*;
    use crate::runtime::manifest::PackEntry;

    #[test]
    fn old_paths_still_compile_and_agree() {
        let info = ModelInfo {
            key: "m".into(),
            backbone: "resnet".into(),
            num_classes: 10,
            image_size: 8,
            channels: 3,
            poly: false,
            param_size: 1,
            mask_size: 128,
            mask_layers: vec![PackEntry {
                name: "a".into(),
                shape: vec![2, 8, 8],
                offset: 0,
                size: 128,
            }],
            param_entries: vec![],
            artifacts: Default::default(),
        };
        let m = Mask::full(128);
        let tr: Trace = simulate(&info, &m, &crate::pi::LAN);
        assert_eq!(tr.gc_bytes, 128 * 2048);
        let (a, s) = compare(&info, &m, &crate::pi::LAN);
        assert!(a > 0.0 && s > 0.0);
    }
}
