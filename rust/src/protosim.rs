//! Protocol-level Private-Inference simulator (DELPHI-style hybrid).
//!
//! The analytic model in [`crate::picost`] prices a whole inference with
//! closed-form constants. This module instead *walks the protocol*: it
//! simulates the online phase of a DELPHI-like two-party hybrid (client
//! holds the input, server holds the weights) layer by layer over a real
//! (model, mask) pair, emitting the actual message sequence — sizes,
//! directions, rounds — so that schedule-level effects are visible:
//! a fully-linearized layer drops its GC round entirely, masked layers
//! shrink their GC payload proportionally, and the round count depends on
//! which layers still hold ReLUs (exactly what BCD changes).
//!
//! This is a *communication/cost* simulation, not a cryptographic
//! implementation: payload sizes follow the published DELPHI/GAZELLE
//! constants, and no secret data is involved.

use crate::model::Mask;
use crate::picost::Protocol;
use crate::runtime::manifest::ModelInfo;

/// Direction of one simulated message.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dir {
    ClientToServer,
    ServerToClient,
}

/// One online-phase message.
#[derive(Clone, Debug)]
pub struct Message {
    pub layer: usize,
    pub dir: Dir,
    pub bytes: u64,
    pub what: &'static str,
}

/// Full online-phase trace of one private inference.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub messages: Vec<Message>,
    /// Communication rounds (direction changes / layer barriers).
    pub rounds: usize,
    /// Total garbled-circuit payload [bytes].
    pub gc_bytes: u64,
    /// Total share-transfer payload [bytes].
    pub share_bytes: u64,
    /// Local compute charged to GC evaluation [s].
    pub gc_compute_secs: f64,
    /// Local compute charged to linear layers under shares [s].
    pub linear_compute_secs: f64,
}

impl Trace {
    pub fn total_bytes(&self) -> u64 {
        self.gc_bytes + self.share_bytes
    }

    /// End-to-end online latency under a network model: serialized
    /// transfers + per-round RTTs + local compute.
    pub fn latency_secs(&self, proto: &Protocol) -> f64 {
        self.total_bytes() as f64 / proto.bandwidth
            + self.rounds as f64 * proto.rtt
            + self.gc_compute_secs
            + self.linear_compute_secs
    }
}

/// Per-element share width (DELPHI uses a 32-bit prime field).
const SHARE_BYTES: u64 = 4;

/// Simulate the online phase for `mask` over `info`'s layer sequence.
///
/// Layer walk (DELPHI online):
///   1. client sends its masked input share (once),
///   2. per linear layer: server evaluates under additive shares — local
///      compute only (preprocessing already exchanged the Beaver/HE state),
///   3. per activation layer with k > 0 ReLUs: one GC exchange —
///      server→client garbled tables for k ReLUs, client→server the
///      re-shared result (k field elements). Linearized slots (identity or
///      polynomial) stay inside the share arithmetic: zero communication.
///   4. server sends the logit share back (once).
pub fn simulate(info: &ModelInfo, mask: &Mask, proto: &Protocol) -> Trace {
    let mut tr = Trace::default();
    let hist = mask.layer_histogram(info);

    // 1. input share upload.
    let input_elems = (info.channels * info.image_size * info.image_size) as u64;
    tr.push(Message {
        layer: 0,
        dir: Dir::ClientToServer,
        bytes: input_elems * SHARE_BYTES,
        what: "input share",
    });

    let mut prev_c = info.channels as f64;
    for (l, entry) in info.mask_layers.iter().enumerate() {
        // 2. the conv feeding this activation, under shares (local).
        let (c, h, w) = (
            entry.shape[0] as f64,
            entry.shape[1] as f64,
            entry.shape[2] as f64,
        );
        let macs = c * h * w * prev_c * 9.0;
        tr.linear_compute_secs += macs / proto.he_macs_per_sec;
        prev_c = c;

        // 3. GC exchange for the surviving ReLUs of this layer.
        let k = hist[l] as u64;
        if k > 0 {
            tr.push(Message {
                layer: l,
                dir: Dir::ServerToClient,
                bytes: k * proto.gc_bytes_per_relu as u64,
                what: "garbled ReLU tables",
            });
            tr.push(Message {
                layer: l,
                dir: Dir::ClientToServer,
                bytes: k * SHARE_BYTES,
                what: "re-shared activations",
            });
            tr.gc_compute_secs += k as f64 * proto.gc_secs_per_relu;
        }
    }

    // 4. logit share download.
    tr.push(Message {
        layer: info.mask_layers.len(),
        dir: Dir::ServerToClient,
        bytes: info.num_classes as u64 * SHARE_BYTES,
        what: "logit share",
    });
    tr
}

impl Trace {
    fn push(&mut self, m: Message) {
        match m.what {
            "garbled ReLU tables" => self.gc_bytes += m.bytes,
            _ => self.share_bytes += m.bytes,
        }
        // A round per direction flip (the first message opens round 1).
        if self
            .messages
            .last()
            .map(|prev| prev.dir != m.dir)
            .unwrap_or(true)
        {
            self.rounds += 1;
        }
        self.messages.push(m);
    }
}

/// Side-by-side of the analytic estimate and the simulated trace — used by
/// tests and the `picost --simulate` CLI to keep the two models honest.
pub fn compare(info: &ModelInfo, mask: &Mask, proto: &Protocol) -> (f64, f64) {
    let analytic = crate::picost::estimate_state(info, mask, proto).total_secs;
    let simulated = simulate(info, mask, proto).latency_secs(proto);
    (analytic, simulated)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::picost::{lan, wan};
    use crate::runtime::manifest::PackEntry;

    fn fake_info() -> ModelInfo {
        ModelInfo {
            key: "m".into(),
            backbone: "resnet".into(),
            num_classes: 10,
            image_size: 8,
            channels: 3,
            poly: false,
            param_size: 1,
            mask_size: 192,
            mask_layers: vec![
                PackEntry { name: "a".into(), shape: vec![2, 8, 8], offset: 0, size: 128 },
                PackEntry { name: "b".into(), shape: vec![4, 4, 4], offset: 128, size: 64 },
            ],
            param_entries: vec![],
            artifacts: Default::default(),
        }
    }

    #[test]
    fn full_mask_trace_structure() {
        let info = fake_info();
        let tr = simulate(&info, &Mask::full(192), &lan());
        // input + 2 x (tables + reshare) + logits = 6 messages.
        assert_eq!(tr.messages.len(), 6);
        assert_eq!(tr.gc_bytes, 192 * 2048);
        assert!(tr.rounds >= 4);
        assert!(tr.latency_secs(&lan()) > 0.0);
    }

    #[test]
    fn linearized_layer_drops_its_round() {
        let info = fake_info();
        let full = simulate(&info, &Mask::full(192), &lan());
        let mut m = Mask::full(192);
        m.remove_layer(&info, 1);
        let cut = simulate(&info, &m, &lan());
        assert_eq!(cut.messages.len(), full.messages.len() - 2);
        assert!(cut.rounds < full.rounds);
        assert_eq!(cut.gc_bytes, 128 * 2048);
        // Linear compute unchanged: convs still run under shares.
        assert!((cut.linear_compute_secs - full.linear_compute_secs).abs() < 1e-12);
    }

    #[test]
    fn gc_bytes_proportional_to_budget() {
        let info = fake_info();
        let mut m = Mask::full(192);
        let drop: Vec<usize> = (0..96).collect();
        m.apply_removal(&drop).unwrap();
        let tr = simulate(&info, &m, &wan());
        assert_eq!(tr.gc_bytes, 96 * 2048);
    }

    #[test]
    fn simulation_agrees_with_analytic_model() {
        // Round accounting is aligned between the two models (2 flips per
        // GC layer + 2 endpoint transfers); residual differences are the
        // share-transfer bytes the analytic model folds into constants.
        let info = fake_info();
        for proto in [lan(), wan()] {
            for keep in [192usize, 100, 10] {
                let mut m = Mask::full(192);
                if keep < 192 {
                    let drop: Vec<usize> = (0..192 - keep).collect();
                    m.apply_removal(&drop).unwrap();
                }
                let (a, s) = compare(&info, &m, &proto);
                let ratio = s / a;
                assert!(
                    (0.3..=3.0).contains(&ratio),
                    "{}@{keep}: analytic {a:.6}s vs sim {s:.6}s",
                    proto.name
                );
            }
        }
    }

    #[test]
    fn wan_latency_dominated_by_gc_traffic() {
        let info = fake_info();
        let tr = simulate(&info, &Mask::full(192), &wan());
        let gc_time = tr.gc_bytes as f64 / wan().bandwidth;
        assert!(gc_time > tr.share_bytes as f64 / wan().bandwidth);
    }
}
