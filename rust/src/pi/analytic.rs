//! Closed-form Private-Inference cost model — why ReLU budgets matter.
//!
//! The paper's motivation (after DELPHI, GAZELLE): in hybrid HE/MPC
//! protocols, *linear* layers run under additively-homomorphic encryption
//! or pre-shared Beaver triples, while each *ReLU* needs a garbled-circuit
//! (GC) evaluation costing kilobytes of online communication. ReLU count
//! therefore dominates online latency. This module turns a (model, mask)
//! pair into estimated online bytes/latency so experiments can report the
//! PI-latency implication of every budget. Constants live in the
//! [`Protocol`] registry ([`crate::pi::protocol`]); they follow DELPHI's
//! reported costs and are estimates, clearly labelled as such in reports.
//!
//! Each masked layer costs one HE↔GC share-translation round trip, which
//! is why `round_secs` scales with *active* layer count, not ReLU count.
//! The message-level dual of this model is [`crate::pi::trace`]; the
//! [`CostModel`] trait gives both one typed entry point.

use super::protocol::Protocol;
use super::{CostModel, InferenceCost};
use crate::model::Mask;
use crate::runtime::manifest::ModelInfo;

/// Estimated online cost of one private inference.
#[derive(Clone, Debug)]
pub struct CostReport {
    pub protocol: &'static str,
    pub relus: usize,
    pub macs: f64,
    pub online_bytes: f64,
    /// Communication + GC compute for the non-linear layers [s].
    pub relu_secs: f64,
    /// HE evaluation of the linear layers [s].
    pub linear_secs: f64,
    /// Round-trip latency across active masked layers [s].
    pub round_secs: f64,
    pub total_secs: f64,
}

/// Streaming per-layer MAC estimate over a manifest's mask-layer table.
///
/// Full-shape entries `[C, H, W]` (the MLP family's spatially-packed
/// layers and the unit-test fixtures) are priced exactly as written: a
/// 3x3 conv from the previous channel count into `C x H x W`. Per-channel
/// entries `[C]` (both conv families and MLP hidden layers pack one mask
/// slot per channel/unit) carry no spatial extent, so the walk tracks an
/// approximate side length: it starts at the input image size and halves
/// whenever the channel count strictly grows — the standard
/// stage-transition stride pattern of the ResNet/WRN backbones. An
/// analytic estimate — good to a small constant factor, which is enough
/// for the relative PI-latency comparisons it feeds (MACs never gate).
pub(crate) struct MacWalk {
    prev_c: f64,
    side: f64,
}

impl MacWalk {
    pub(crate) fn new(info: &ModelInfo) -> MacWalk {
        MacWalk { prev_c: info.channels as f64, side: info.image_size as f64 }
    }

    /// MACs of the linear layer feeding a mask entry of `shape`.
    pub(crate) fn layer(&mut self, shape: &[usize]) -> f64 {
        let (c, hw) = match shape {
            [c, h, w] => (*c as f64, (h * w) as f64),
            [c] => {
                if *c as f64 > self.prev_c {
                    self.side = (self.side / 2.0).max(1.0);
                }
                (*c as f64, self.side * self.side)
            }
            other => (other.first().copied().unwrap_or(1) as f64, 1.0),
        };
        let macs = c * hw * self.prev_c * 9.0;
        self.prev_c = c;
        macs
    }

    /// MACs of the final dense head.
    pub(crate) fn head(&self, num_classes: usize) -> f64 {
        self.prev_c * num_classes as f64
    }
}

/// Estimate multiply-accumulate count of the network from the manifest's
/// mask-layer table (see [`MacWalk`] for the per-shape rules).
pub fn estimate_macs(info: &ModelInfo) -> f64 {
    let mut walk = MacWalk::new(info);
    let mut macs = 0.0f64;
    for e in &info.mask_layers {
        macs += walk.layer(&e.shape);
    }
    macs + walk.head(info.num_classes)
}

/// Online-phase cost for a network with `relus` active ReLUs. Each mask
/// layer that still holds a ReLU costs one GC exchange = two direction
/// flips (tables down, re-shares up); the input/logit share transfers add
/// two endpoint rounds. This matches [`crate::pi::trace`]'s message walk.
pub fn estimate(
    info: &ModelInfo,
    relus: usize,
    active_layers: usize,
    proto: &Protocol,
) -> CostReport {
    let macs = estimate_macs(info);
    let online_bytes = relus as f64 * proto.gc_bytes_per_relu;
    let relu_secs = online_bytes / proto.bandwidth + relus as f64 * proto.gc_secs_per_relu;
    let linear_secs = macs / proto.he_macs_per_sec;
    let round_secs = (2 * active_layers + 2) as f64 * proto.rtt;
    CostReport {
        protocol: proto.name,
        relus,
        macs,
        online_bytes,
        relu_secs,
        linear_secs,
        round_secs,
        total_secs: relu_secs + linear_secs + round_secs,
    }
}

/// Convenience over a model state: counts active layers from the mask.
pub fn estimate_state(info: &ModelInfo, mask: &Mask, proto: &Protocol) -> CostReport {
    let hist = mask.layer_histogram(info);
    let active = hist.iter().filter(|&&h| h > 0).count();
    estimate(info, mask.count(), active, proto)
}

/// The closed-form model as a [`CostModel`]: per-direction bytes use the
/// same closed forms the trace walk realizes message by message, so the
/// two models agree exactly on bytes and rounds and differ only in how
/// they compose latency.
pub struct Analytic;

impl CostModel for Analytic {
    fn name(&self) -> &'static str {
        "analytic"
    }

    fn price(&self, info: &ModelInfo, mask: &Mask, proto: &Protocol) -> InferenceCost {
        let r = estimate_state(info, mask, proto);
        let input_elems = info.channels * info.image_size * info.image_size;
        let hist = mask.layer_histogram(info);
        let active = hist.iter().filter(|&&h| h > 0).count();
        InferenceCost {
            model: self.name(),
            protocol: proto.name,
            relus: r.relus,
            active_layers: active,
            rounds: 2 * active + 2,
            up_bytes: (input_elems + r.relus) as u64 * super::trace::SHARE_BYTES,
            down_bytes: r.relus as u64 * proto.gc_bytes_per_relu as u64
                + info.num_classes as u64 * super::trace::SHARE_BYTES,
            latency_secs: r.total_secs,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::protocol::{LAN, WAN};
    use super::*;
    use crate::runtime::manifest::PackEntry;

    fn fake_info() -> ModelInfo {
        ModelInfo {
            key: "m".into(),
            backbone: "resnet".into(),
            num_classes: 10,
            image_size: 8,
            channels: 3,
            poly: false,
            param_size: 1,
            mask_size: 128 + 64,
            mask_layers: vec![
                PackEntry { name: "a".into(), shape: vec![2, 8, 8], offset: 0, size: 128 },
                PackEntry { name: "b".into(), shape: vec![4, 4, 4], offset: 128, size: 64 },
            ],
            param_entries: vec![],
            artifacts: Default::default(),
        }
    }

    #[test]
    fn macs_analytic() {
        // conv1: 2*8*8*3*9 = 3456 ; conv2: 4*4*4*2*9 = 1152 ; head 4*10=40.
        assert_eq!(estimate_macs(&fake_info()), 3456.0 + 1152.0 + 40.0);
    }

    #[test]
    fn per_channel_shapes_estimate_without_panicking() {
        // Conv-family manifests pack one mask slot per channel (`[C]`); the
        // pre-PR-9 estimator indexed shape[1] and panicked on them. The walk
        // halves its side at each channel increase: 16x16 @8ch, 8x8 @16ch.
        let mut info = fake_info();
        info.image_size = 16;
        info.mask_size = 24;
        info.mask_layers = vec![
            PackEntry { name: "s".into(), shape: vec![8], offset: 0, size: 8 },
            PackEntry { name: "b".into(), shape: vec![16], offset: 8, size: 16 },
        ];
        let want = 8.0 * 256.0 * 3.0 * 9.0 + 16.0 * 64.0 * 8.0 * 9.0 + 16.0 * 10.0;
        assert_eq!(estimate_macs(&info), want);
    }

    #[test]
    fn fewer_relus_cheaper() {
        let info = fake_info();
        let full = estimate(&info, 192, 2, &LAN);
        let half = estimate(&info, 96, 2, &LAN);
        assert!(half.total_secs < full.total_secs);
        assert_eq!(half.linear_secs, full.linear_secs, "linear part unaffected");
    }

    #[test]
    fn wan_dominated_by_comms() {
        let info = fake_info();
        let r = estimate(&info, 10_000, 2, &WAN);
        assert!(r.relu_secs > r.linear_secs);
    }

    #[test]
    fn empty_layers_drop_rounds() {
        let info = fake_info();
        let mut m = Mask::full(192);
        m.remove_layer(&info, 1);
        let r = estimate_state(&info, &m, &LAN);
        assert_eq!(r.relus, 128);
        let full = estimate_state(&info, &Mask::full(192), &LAN);
        assert!(r.round_secs < full.round_secs);
    }

    #[test]
    fn analytic_cost_model_counts_match_closed_forms() {
        let info = fake_info();
        let m = Mask::full(192);
        let c = Analytic.price(&info, &m, &LAN);
        assert_eq!((c.model, c.protocol), ("analytic", "LAN"));
        assert_eq!((c.relus, c.active_layers, c.rounds), (192, 2, 6));
        assert_eq!(c.up_bytes, (3 * 8 * 8 + 192) * 4);
        assert_eq!(c.down_bytes, 192 * 2048 + 10 * 4);
        assert!(c.latency_secs > 0.0);
    }
}
