//! Production-scale PI serving simulator (DESIGN.md §14).
//!
//! The per-inference models ([`crate::pi::analytic`], [`crate::pi::trace`])
//! price ONE private inference. This module answers the question users
//! actually ask of a linearized network — *what does a BCD mask buy at
//! fleet scale?* — with a deterministic discrete-event simulation that
//! multiplexes many concurrent private inferences over one simulated
//! server + link pair:
//!
//! - **Seeded arrival process** — per-client exponential inter-arrival
//!   times (Poisson traffic) drawn from a forked [`Rng`] stream per
//!   client, so traces are reproducible and clients are decorrelated.
//! - **Per-request round pipelining** — every request independently
//!   replays the [`crate::pi::trace::script`] step sequence; requests
//!   interleave freely on the shared uplink/downlink/GEMM resources.
//! - **Preprocessing-phase scheduling** — a single server-side garbler
//!   prepares each request's GC tables (DELPHI's offline phase) in
//!   arrival order, running at most `prep_ahead` requests ahead of the
//!   arrivals seen so far; a request's online phase starts only when it
//!   has both arrived and been prepped.
//! - **Batch aggregation on linear layers** — one server GEMM unit
//!   serves same-layer jobs from up to `batch_window` requests in one
//!   batched evaluation; co-batched followers cost
//!   [`BATCH_FOLLOWER_SHIFT`] (base >> 2 = 25%) of the leader, the
//!   amortization the window knob trades against latency.
//!
//! # Determinism contract
//!
//! The event loop is **bit-deterministic given the seed**, across hosts
//! and repeated runs: all simulated time is integer nanoseconds, ties
//! break on a monotone event sequence number, every queue is FIFO, and
//! the only transcendental on the hot path (the exponential sampler's
//! log) is [`det_ln`] — basic IEEE arithmetic only, no platform `libm`.
//! The serve bench tier asserts `run == rerun` by full report equality,
//! and every gated metric is an integer count.
//!
//! # Percentile rule
//!
//! Latency percentiles use the **nearest-rank** method on the sorted
//! per-request latencies: `p`-th percentile = the element at 1-based rank
//! `ceil(p * n / 100)` (computed in integer arithmetic as
//! `(p * n + 99) / 100`). No interpolation — the reported value is always
//! an observed latency, and the rule is exact in integers.

use super::protocol::Protocol;
use super::trace::{script, Step};
use crate::derive_serde;
use crate::model::Mask;
use crate::runtime::manifest::ModelInfo;
use crate::util::prng::Rng;
use anyhow::{ensure, Result};
use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};

/// Offline garbling costs this multiple of the online GC evaluation time
/// per ReLU (DELPHI reports garbling ~2x evaluation).
pub const PREP_GARBLE_FACTOR: f64 = 2.0;

/// Each co-batched GEMM follower costs `base >> BATCH_FOLLOWER_SHIFT`
/// (25% of the leader) — integer arithmetic, so batched service times
/// stay exact.
pub const BATCH_FOLLOWER_SHIFT: u32 = 2;

/// Serving-simulation knobs; the config surface behind the `pi.*` keys.
#[derive(Clone, Debug, PartialEq)]
pub struct ServeConfig {
    /// Concurrent clients, each with its own arrival stream.
    pub clients: usize,
    /// Inferences per client.
    pub requests: usize,
    /// Per-client Poisson arrival rate [requests/s].
    pub arrival_rate: f64,
    /// Max same-layer GEMM jobs batched into one server evaluation.
    pub batch_window: usize,
    /// How many requests the garbler may run ahead of observed arrivals.
    pub prep_ahead: usize,
    /// Arrival-process seed; same seed → bit-identical report.
    pub seed: u64,
}

impl ServeConfig {
    /// The `pi.*` slice of an experiment (protocol selection stays by
    /// name — see [`crate::pi::protocol::find`]).
    pub fn from_experiment(exp: &crate::config::Experiment) -> ServeConfig {
        ServeConfig {
            clients: exp.pi.clients,
            requests: exp.pi.requests,
            arrival_rate: exp.pi.arrival_rate,
            batch_window: exp.pi.batch_window,
            prep_ahead: exp.pi.prep_ahead,
            seed: exp.pi.seed,
        }
    }
}

/// One serving simulation's results. Count-valued fields are exact and
/// arrival-timing-independent (they gate in `BENCH_serve.json`); the
/// float-valued latency/throughput fields are bit-deterministic for a
/// seed but host-advisory in the bench gate.
#[derive(Clone, Debug, PartialEq)]
pub struct ServeReport {
    pub protocol: String,
    pub clients: usize,
    pub requests: usize,
    /// Inferences that ran to completion (always `clients * requests`).
    pub completed: usize,
    /// Surviving ReLUs of the served mask.
    pub relus: usize,
    /// Mask layers still holding at least one ReLU.
    pub active_layers: usize,
    /// Online rounds of one inference (`2 * active_layers + 2`).
    pub rounds_per_inference: usize,
    /// Total online rounds across all completed inferences.
    pub online_rounds: usize,
    /// Total client→server payload [bytes].
    pub up_bytes: usize,
    /// Total server→client payload [bytes].
    pub down_bytes: usize,
    /// Linear-layer jobs entering the GEMM unit (completed x layers).
    pub gemm_jobs: usize,
    /// Batched evaluations the GEMM unit actually ran (≤ `gemm_jobs`;
    /// the batching win — timing-dependent, so not baseline-gated).
    pub gemm_batches: usize,
    /// Requests whose GC tables were garbled (always `completed`).
    pub prep_completed: usize,
    /// Discrete events processed (timing-dependent; not baseline-gated).
    pub events: usize,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub mean_ms: f64,
    /// Simulated time until the last completion [s].
    pub makespan_secs: f64,
    /// `completed / makespan_secs`.
    pub throughput_rps: f64,
}
derive_serde!(ServeReport {
    protocol,
    clients,
    requests,
    completed,
    relus,
    active_layers,
    rounds_per_inference,
    online_rounds,
    up_bytes,
    down_bytes,
    gemm_jobs,
    gemm_batches,
    prep_completed,
    events,
    p50_ms,
    p95_ms,
    p99_ms,
    mean_ms,
    makespan_secs,
    throughput_rps,
});

/// Deterministic natural logarithm over basic IEEE arithmetic (no libm):
/// frexp-style decomposition `x = m * 2^e` with `m` centered on
/// `[1/sqrt2, sqrt2)`, then `ln(m) = 2 atanh((m-1)/(m+1))` by its odd
/// power series (|z| ≤ 0.172, 13 terms ≪ 1 ulp). Guarantees the arrival
/// sampler produces bit-identical streams on every platform.
fn det_ln(x: f64) -> f64 {
    debug_assert!(x > 0.0 && x.is_finite());
    let bits = x.to_bits();
    let mut e = ((bits >> 52) & 0x7ff) as i64 - 1023;
    let mut m = f64::from_bits((bits & 0x000f_ffff_ffff_ffff) | 0x3ff0_0000_0000_0000);
    if m > std::f64::consts::SQRT_2 {
        m /= 2.0;
        e += 1;
    }
    let z = (m - 1.0) / (m + 1.0);
    let z2 = z * z;
    let mut term = z;
    let mut atanh = 0.0f64;
    for k in 0..13u32 {
        atanh += term / (2 * k + 1) as f64;
        term *= z2;
    }
    e as f64 * std::f64::consts::LN_2 + 2.0 * atanh
}

/// Nearest-rank percentile over sorted samples (see module docs for the
/// exact rule). `samples` must be non-empty and sorted ascending.
fn percentile_ns(sorted: &[u64], p: usize) -> u64 {
    debug_assert!(!sorted.is_empty() && (1..=100).contains(&p));
    let rank = (p * sorted.len() + 99) / 100; // ceil, 1-based
    sorted[rank.max(1) - 1]
}

/// Discrete event kinds. `Ord` is derived only so events can ride the
/// heap tuple; ties never reach it (the sequence number is unique).
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
enum Ev {
    Arrive(usize),
    PrepDone(usize),
    UpXmitEnd(usize),
    DownXmitEnd(usize),
    UpDelivered(usize),
    DownDelivered(usize),
    GcDone(usize),
    LinearDone(Vec<usize>),
}

struct Sim<'a> {
    steps: &'a [Step],
    /// Base GEMM service time per mask layer [ns], from the script.
    lin_ns: Vec<u64>,
    prop_ns: u64,
    bandwidth: f64,
    gc_ns_per_relu: f64,
    cfg: &'a ServeConfig,
    heap: BinaryHeap<Reverse<(u64, u64, Ev)>>,
    seq: u64,
    // Per-request state.
    arrive_ns: Vec<u64>,
    step_idx: Vec<usize>,
    arrived: Vec<bool>,
    prepped: Vec<bool>,
    started: Vec<bool>,
    latencies_ns: Vec<u64>,
    // Shared resources: two half-duplex links, one GEMM unit, one garbler.
    up_q: VecDeque<(usize, u64)>,
    up_busy: bool,
    down_q: VecDeque<(usize, u64)>,
    down_busy: bool,
    lin_q: VecDeque<(usize, usize)>,
    lin_busy: bool,
    next_prep: usize,
    prep_busy: bool,
    prep_ns: u64,
    arrived_count: usize,
    // Tallies.
    events: usize,
    up_bytes: u64,
    down_bytes: u64,
    gemm_jobs: usize,
    gemm_batches: usize,
    prep_completed: usize,
    last_ns: u64,
}

impl Sim<'_> {
    fn push_ev(&mut self, t: u64, ev: Ev) {
        self.seq += 1;
        self.heap.push(Reverse((t, self.seq, ev)));
    }

    fn xmit_ns(&self, bytes: u64) -> u64 {
        (bytes as f64 / self.bandwidth * 1e9).round() as u64
    }

    /// Dispatch the current script step of `req` at time `now`.
    fn advance(&mut self, req: usize, now: u64) {
        let Some(step) = self.steps.get(self.step_idx[req]) else {
            self.latencies_ns.push(now - self.arrive_ns[req]);
            self.last_ns = self.last_ns.max(now);
            return;
        };
        match *step {
            Step::Up { bytes, .. } => {
                self.up_bytes += bytes;
                self.up_q.push_back((req, bytes));
                self.try_up(now);
            }
            Step::Down { bytes, .. } => {
                self.down_bytes += bytes;
                self.down_q.push_back((req, bytes));
                self.try_down(now);
            }
            Step::Linear { layer, .. } => {
                self.gemm_jobs += 1;
                self.lin_q.push_back((req, layer));
                self.try_linear(now);
            }
            Step::GcEval { relus, .. } => {
                let dt = (relus as f64 * self.gc_ns_per_relu).round() as u64;
                self.push_ev(now + dt, Ev::GcDone(req));
            }
        }
    }

    /// Completion of the current step: move the cursor and dispatch the
    /// next one.
    fn step_done(&mut self, req: usize, now: u64) {
        self.step_idx[req] += 1;
        self.advance(req, now);
    }

    fn try_up(&mut self, now: u64) {
        if self.up_busy {
            return;
        }
        if let Some(&(req, bytes)) = self.up_q.front() {
            self.up_q.pop_front();
            self.up_busy = true;
            self.push_ev(now + self.xmit_ns(bytes), Ev::UpXmitEnd(req));
        }
    }

    fn try_down(&mut self, now: u64) {
        if self.down_busy {
            return;
        }
        if let Some(&(req, bytes)) = self.down_q.front() {
            self.down_q.pop_front();
            self.down_busy = true;
            self.push_ev(now + self.xmit_ns(bytes), Ev::DownXmitEnd(req));
        }
    }

    /// When the GEMM unit is free, pull the head job plus up to
    /// `batch_window - 1` queued jobs *of the same layer* (from anywhere
    /// in the queue — cross-client aggregation) into one batched
    /// evaluation.
    fn try_linear(&mut self, now: u64) {
        if self.lin_busy || self.lin_q.is_empty() {
            return;
        }
        let layer = self.lin_q[0].1;
        let mut jobs = Vec::new();
        let mut rest = VecDeque::with_capacity(self.lin_q.len());
        while let Some((r, l)) = self.lin_q.pop_front() {
            if l == layer && jobs.len() < self.cfg.batch_window {
                jobs.push(r);
            } else {
                rest.push_back((r, l));
            }
        }
        self.lin_q = rest;
        let base = self.lin_ns[layer];
        let service = base + (jobs.len() as u64 - 1) * (base >> BATCH_FOLLOWER_SHIFT);
        self.lin_busy = true;
        self.gemm_batches += 1;
        self.push_ev(now + service, Ev::LinearDone(jobs));
    }

    /// The garbler preps requests in arrival order, at most
    /// `prep_ahead` ahead of the arrivals observed so far.
    fn try_prep(&mut self, now: u64) {
        if self.prep_busy
            || self.next_prep >= self.arrive_ns.len()
            || self.next_prep >= self.arrived_count + self.cfg.prep_ahead
        {
            return;
        }
        let req = self.next_prep;
        self.next_prep += 1;
        self.prep_busy = true;
        self.push_ev(now + self.prep_ns, Ev::PrepDone(req));
    }

    fn maybe_start(&mut self, req: usize, now: u64) {
        if self.arrived[req] && self.prepped[req] && !self.started[req] {
            self.started[req] = true;
            self.advance(req, now);
        }
    }
}

/// Run the serving simulation: `cfg.clients * cfg.requests` private
/// inferences of `mask` over `info`, multiplexed on one `proto` link
/// pair. Bit-deterministic for a given `cfg.seed` (see module docs).
///
/// Latency composition differs from
/// [`Trace::latency_secs`](crate::pi::trace::Trace::latency_secs) by
/// design: the trace folds one full RTT per round,
/// while the event loop charges serialized transmission plus one-way
/// propagation (`rtt / 2`) per message and makes queueing delays — the
/// point of the exercise — emerge from resource contention.
pub fn serve(
    info: &ModelInfo,
    mask: &Mask,
    proto: &Protocol,
    cfg: &ServeConfig,
) -> Result<ServeReport> {
    ensure!(cfg.clients >= 1, "pi.clients must be >= 1");
    ensure!(cfg.requests >= 1, "pi.requests must be >= 1");
    ensure!(cfg.batch_window >= 1, "pi.batch_window must be >= 1");
    ensure!(cfg.prep_ahead >= 1, "pi.prep_ahead must be >= 1");
    ensure!(
        cfg.arrival_rate > 0.0 && cfg.arrival_rate.is_finite(),
        "pi.arrival_rate must be positive"
    );

    let steps = script(info, mask, proto);
    let n_layers = info.mask_layers.len();
    let mut lin_ns = vec![0u64; n_layers];
    let mut rounds_per_inference = 0usize;
    let mut last_dir_up: Option<bool> = None;
    for s in &steps {
        match *s {
            Step::Linear { layer, macs } => {
                lin_ns[layer] = (macs / proto.he_macs_per_sec * 1e9).round() as u64;
            }
            Step::Up { .. } => {
                if last_dir_up != Some(true) {
                    rounds_per_inference += 1;
                }
                last_dir_up = Some(true);
            }
            Step::Down { .. } => {
                if last_dir_up != Some(false) {
                    rounds_per_inference += 1;
                }
                last_dir_up = Some(false);
            }
            Step::GcEval { .. } => {}
        }
    }

    // Seeded Poisson arrivals: one forked stream per client, sorted into
    // one global order on (time, client, request) — the request index
    // space of the whole simulation.
    let total = cfg.clients * cfg.requests;
    let mut root = Rng::new(cfg.seed);
    let mut arrivals: Vec<(u64, usize, usize)> = Vec::with_capacity(total);
    for c in 0..cfg.clients {
        let mut r = root.fork(c as u64);
        let mut t = 0.0f64;
        for k in 0..cfg.requests {
            let u = r.f64();
            t += -det_ln(1.0 - u) / cfg.arrival_rate;
            arrivals.push(((t * 1e9).round() as u64, c, k));
        }
    }
    arrivals.sort_unstable();

    let hist = mask.layer_histogram(info);
    let relus = mask.count();
    let prep_ns =
        (relus as f64 * proto.gc_secs_per_relu * PREP_GARBLE_FACTOR * 1e9).round() as u64;

    let mut sim = Sim {
        steps: &steps,
        lin_ns,
        prop_ns: (proto.rtt / 2.0 * 1e9).round() as u64,
        bandwidth: proto.bandwidth,
        gc_ns_per_relu: proto.gc_secs_per_relu * 1e9,
        cfg,
        heap: BinaryHeap::new(),
        seq: 0,
        arrive_ns: arrivals.iter().map(|&(t, _, _)| t).collect(),
        step_idx: vec![0; total],
        arrived: vec![false; total],
        prepped: vec![false; total],
        started: vec![false; total],
        latencies_ns: Vec::with_capacity(total),
        up_q: VecDeque::new(),
        up_busy: false,
        down_q: VecDeque::new(),
        down_busy: false,
        lin_q: VecDeque::new(),
        lin_busy: false,
        next_prep: 0,
        prep_busy: false,
        prep_ns,
        arrived_count: 0,
        events: 0,
        up_bytes: 0,
        down_bytes: 0,
        gemm_jobs: 0,
        gemm_batches: 0,
        prep_completed: 0,
        last_ns: 0,
    };

    for req in 0..total {
        let t = sim.arrive_ns[req];
        sim.push_ev(t, Ev::Arrive(req));
    }
    sim.try_prep(0);

    while let Some(Reverse((now, _, ev))) = sim.heap.pop() {
        sim.events += 1;
        match ev {
            Ev::Arrive(req) => {
                sim.arrived[req] = true;
                sim.arrived_count += 1;
                sim.maybe_start(req, now);
                sim.try_prep(now);
            }
            Ev::PrepDone(req) => {
                sim.prep_busy = false;
                sim.prep_completed += 1;
                sim.prepped[req] = true;
                sim.maybe_start(req, now);
                sim.try_prep(now);
            }
            Ev::UpXmitEnd(req) => {
                sim.up_busy = false;
                let t = now + sim.prop_ns;
                sim.push_ev(t, Ev::UpDelivered(req));
                sim.try_up(now);
            }
            Ev::DownXmitEnd(req) => {
                sim.down_busy = false;
                let t = now + sim.prop_ns;
                sim.push_ev(t, Ev::DownDelivered(req));
                sim.try_down(now);
            }
            Ev::UpDelivered(req) | Ev::DownDelivered(req) | Ev::GcDone(req) => {
                sim.step_done(req, now);
            }
            Ev::LinearDone(jobs) => {
                sim.lin_busy = false;
                for req in jobs {
                    sim.step_done(req, now);
                }
                sim.try_linear(now);
            }
        }
    }

    ensure!(
        sim.latencies_ns.len() == total,
        "serve event loop stalled: {}/{} inferences completed",
        sim.latencies_ns.len(),
        total
    );
    let mut sorted = sim.latencies_ns.clone();
    sorted.sort_unstable();
    let sum_ns: u64 = sorted.iter().sum();
    let makespan_secs = sim.last_ns as f64 / 1e9;
    Ok(ServeReport {
        protocol: proto.name.to_string(),
        clients: cfg.clients,
        requests: cfg.requests,
        completed: total,
        relus,
        active_layers: hist.iter().filter(|&&h| h > 0).count(),
        rounds_per_inference,
        online_rounds: rounds_per_inference * total,
        up_bytes: sim.up_bytes as usize,
        down_bytes: sim.down_bytes as usize,
        gemm_jobs: sim.gemm_jobs,
        gemm_batches: sim.gemm_batches,
        prep_completed: sim.prep_completed,
        events: sim.events,
        p50_ms: percentile_ns(&sorted, 50) as f64 / 1e6,
        p95_ms: percentile_ns(&sorted, 95) as f64 / 1e6,
        p99_ms: percentile_ns(&sorted, 99) as f64 / 1e6,
        mean_ms: sum_ns as f64 / sorted.len() as f64 / 1e6,
        makespan_secs,
        throughput_rps: total as f64 / makespan_secs.max(1e-12),
    })
}

#[cfg(test)]
mod tests {
    use super::super::protocol::{LAN, WAN};
    use super::super::trace::simulate;
    use super::*;
    use crate::runtime::manifest::PackEntry;

    fn fake_info() -> ModelInfo {
        ModelInfo {
            key: "m".into(),
            backbone: "resnet".into(),
            num_classes: 10,
            image_size: 8,
            channels: 3,
            poly: false,
            param_size: 1,
            mask_size: 192,
            mask_layers: vec![
                PackEntry { name: "a".into(), shape: vec![2, 8, 8], offset: 0, size: 128 },
                PackEntry { name: "b".into(), shape: vec![4, 4, 4], offset: 128, size: 64 },
            ],
            param_entries: vec![],
            artifacts: Default::default(),
        }
    }

    fn cfg() -> ServeConfig {
        ServeConfig {
            clients: 5,
            requests: 4,
            arrival_rate: 50.0,
            batch_window: 4,
            prep_ahead: 3,
            seed: 0x5EED,
        }
    }

    #[test]
    fn det_ln_matches_std_ln() {
        for x in [1e-9, 0.001, 0.3, 0.5, 0.9999, 1.0, 1.5, 2.0, 7.0, 1e6] {
            let (a, b) = (det_ln(x), f64::ln(x));
            assert!((a - b).abs() <= 1e-12 * b.abs().max(1.0), "ln({x}): {a} vs {b}");
        }
    }

    #[test]
    fn nearest_rank_percentiles() {
        let v: Vec<u64> = (1..=10).collect();
        assert_eq!(percentile_ns(&v, 50), 5);
        assert_eq!(percentile_ns(&v, 95), 10);
        assert_eq!(percentile_ns(&v, 99), 10);
        assert_eq!(percentile_ns(&[42], 50), 42);
        assert_eq!(percentile_ns(&[42], 99), 42);
    }

    #[test]
    fn serve_is_bit_deterministic() {
        let info = fake_info();
        let m = Mask::full(192);
        let a = serve(&info, &m, &WAN, &cfg()).unwrap();
        let b = serve(&info, &m, &WAN, &cfg()).unwrap();
        assert_eq!(a, b);
        let mut other = cfg();
        other.seed ^= 1;
        let c = serve(&info, &m, &WAN, &other).unwrap();
        assert_ne!(a.makespan_secs, c.makespan_secs, "different seeds must shuffle arrivals");
        assert_eq!(a.completed, c.completed);
        assert_eq!((a.up_bytes, a.down_bytes), (c.up_bytes, c.down_bytes));
    }

    #[test]
    fn serve_conserves_trace_bytes_and_rounds() {
        let info = fake_info();
        let mut m = Mask::full(192);
        m.apply_removal(&(0..100).collect::<Vec<_>>()).unwrap();
        let tr = simulate(&info, &m, &LAN);
        let r = serve(&info, &m, &LAN, &cfg()).unwrap();
        assert_eq!(r.completed, 20);
        assert_eq!(r.up_bytes, r.completed * tr.up_bytes() as usize);
        assert_eq!(r.down_bytes, r.completed * tr.down_bytes() as usize);
        assert_eq!(r.rounds_per_inference, tr.rounds);
        assert_eq!(r.online_rounds, tr.rounds * r.completed);
        assert_eq!(r.prep_completed, r.completed);
    }

    #[test]
    fn batching_amortizes_gemm_rounds() {
        let info = fake_info();
        let m = Mask::full(192);
        let mut c1 = cfg();
        c1.batch_window = 1;
        let unbatched = serve(&info, &m, &LAN, &c1).unwrap();
        assert_eq!(unbatched.gemm_batches, unbatched.gemm_jobs, "window 1 cannot batch");
        let batched = serve(&info, &m, &LAN, &cfg()).unwrap();
        assert_eq!(batched.gemm_jobs, unbatched.gemm_jobs);
        assert!(batched.gemm_batches <= batched.gemm_jobs);
    }

    #[test]
    fn fully_linearized_network_serves_in_two_rounds() {
        let info = fake_info();
        let mut m = Mask::full(192);
        m.apply_removal(&(0..192).collect::<Vec<_>>()).unwrap();
        let r = serve(&info, &m, &LAN, &cfg()).unwrap();
        assert_eq!(r.relus, 0);
        assert_eq!(r.active_layers, 0);
        assert_eq!(r.rounds_per_inference, 2, "only input up + logits down remain");
        assert!(r.p99_ms > 0.0);
    }

    #[test]
    fn config_validation_rejects_degenerate_knobs() {
        let info = fake_info();
        let m = Mask::full(192);
        let patches: [fn(&mut ServeConfig); 5] = [
            |c| c.clients = 0,
            |c| c.requests = 0,
            |c| c.batch_window = 0,
            |c| c.prep_ahead = 0,
            |c| c.arrival_rate = 0.0,
        ];
        for patch in patches {
            let mut c = cfg();
            patch(&mut c);
            assert!(serve(&info, &m, &LAN, &c).is_err());
        }
    }

    #[test]
    fn report_roundtrips_through_serde() {
        let info = fake_info();
        let r = serve(&info, &Mask::full(192), &WAN, &cfg()).unwrap();
        let text = crate::util::serde::to_string_pretty(&r);
        let back: ServeReport = crate::util::serde::from_str(&text).unwrap();
        assert_eq!(back, r);
    }
}
