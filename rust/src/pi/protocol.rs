//! The named [`Protocol`] registry — deployment scenarios for the PI cost
//! models and the serving simulator.
//!
//! PR 9 replaced the bare `picost::lan()` / `picost::wan()` free functions
//! with this registry so that every entry point — `cdnl picost --proto`,
//! `cdnl serve --proto`, the `pi.protocol` config key, and the serve bench
//! tier — selects a scenario by *name* and new scenarios need exactly one
//! table row. The old free functions survive as deprecated shims in
//! [`crate::picost`].
//!
//! # Where the constants come from
//!
//! - `gc_bytes_per_relu = 2048`: DELPHI (Mishra et al., USENIX Security
//!   2020) reports ~2 KB of online garbled-circuit communication per ReLU;
//!   the PI baselines reproduced here budget against the same figure —
//!   see DeepReDuce (Jha et al. 2021, <https://arxiv.org/pdf/2103.01396>)
//!   and SNL (Cho et al. 2022, <https://arxiv.org/pdf/2202.02340>), both
//!   abstracted in PAPERS.md, which motivate ReLU count as *the* PI cost
//!   driver.
//! - `gc_secs_per_relu = 88e-6`: DELPHI's reported per-ReLU online GC
//!   compute on commodity CPUs.
//! - `bandwidth` / `rtt`: 1 Gbit/s + 0.5 ms (`lan`), 100 Mbit/s + 40 ms
//!   (`wan`) — the two deployment points the PI literature conventionally
//!   reports (e.g. SENet, Kundu et al. 2023,
//!   <https://arxiv.org/pdf/2301.09254>) — plus 20 Mbit/s + 80 ms
//!   (`mobile`), a last-mile cellular point for the serving simulator's
//!   tail-latency studies.
//! - `he_macs_per_sec = 5e8`: order-of-magnitude additively-homomorphic
//!   MAC throughput for the linear layers; linear cost is reported for
//!   context only and never dominates at the budgets studied.

/// Network + crypto cost constants for one deployment scenario.
#[derive(Clone, Debug, PartialEq)]
pub struct Protocol {
    /// Display name ("LAN"); [`find`] matches it case-insensitively.
    pub name: &'static str,
    /// Online GC bytes exchanged per ReLU evaluation.
    pub gc_bytes_per_relu: f64,
    /// Local GC compute time per ReLU [s].
    pub gc_secs_per_relu: f64,
    /// Link bandwidth [bytes/s].
    pub bandwidth: f64,
    /// Round-trip time [s]; each masked layer costs one round of
    /// share-translation between the HE and GC domains.
    pub rtt: f64,
    /// Homomorphic MAC throughput for linear layers [MACs/s].
    pub he_macs_per_sec: f64,
}

/// DELPHI's GC payload/compute and HE throughput constants, shared by
/// every registered scenario (only the link differs between them).
const GC_BYTES_PER_RELU: f64 = 2048.0;
const GC_SECS_PER_RELU: f64 = 88e-6;
const HE_MACS_PER_SEC: f64 = 5e8;

/// 1 Gbit/s, 0.5 ms RTT — same-datacenter deployment.
pub static LAN: Protocol = Protocol {
    name: "LAN",
    gc_bytes_per_relu: GC_BYTES_PER_RELU,
    gc_secs_per_relu: GC_SECS_PER_RELU,
    bandwidth: 125e6,
    rtt: 0.5e-3,
    he_macs_per_sec: HE_MACS_PER_SEC,
};

/// 100 Mbit/s, 40 ms RTT — client-to-cloud deployment.
pub static WAN: Protocol = Protocol {
    name: "WAN",
    gc_bytes_per_relu: GC_BYTES_PER_RELU,
    gc_secs_per_relu: GC_SECS_PER_RELU,
    bandwidth: 12.5e6,
    rtt: 40e-3,
    he_macs_per_sec: HE_MACS_PER_SEC,
};

/// 20 Mbit/s, 80 ms RTT — last-mile cellular client.
pub static MOBILE: Protocol = Protocol {
    name: "MOBILE",
    gc_bytes_per_relu: GC_BYTES_PER_RELU,
    gc_secs_per_relu: GC_SECS_PER_RELU,
    bandwidth: 2.5e6,
    rtt: 80e-3,
    he_macs_per_sec: HE_MACS_PER_SEC,
};

/// Every registered scenario, table order — the single source of truth
/// for `--proto`, the `pi.protocol` config key and the CLI default rows.
pub fn registry() -> &'static [&'static Protocol] {
    &[&LAN, &WAN, &MOBILE]
}

/// Look up a scenario by name, ASCII-case-insensitively (`"lan"`,
/// `"LAN"`, `"Lan"` all resolve).
pub fn find(name: &str) -> Option<&'static Protocol> {
    registry().iter().find(|p| p.name.eq_ignore_ascii_case(name)).copied()
}

/// Lower-case registry names, for error messages and config validation.
pub fn names() -> Vec<String> {
    registry().iter().map(|p| p.name.to_ascii_lowercase()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_finds_every_name_case_insensitively() {
        assert_eq!(registry().len(), 3);
        for p in registry() {
            assert_eq!(find(p.name), Some(*p));
            assert_eq!(find(&p.name.to_ascii_lowercase()), Some(*p));
        }
        assert_eq!(find("carrier-pigeon"), None);
        assert_eq!(names(), ["lan", "wan", "mobile"]);
    }

    #[test]
    fn links_order_by_quality() {
        assert!(LAN.bandwidth > WAN.bandwidth && WAN.bandwidth > MOBILE.bandwidth);
        assert!(LAN.rtt < WAN.rtt && WAN.rtt < MOBILE.rtt);
        // Crypto constants are deployment-independent.
        for p in registry() {
            assert_eq!(p.gc_bytes_per_relu, GC_BYTES_PER_RELU);
            assert_eq!(p.gc_secs_per_relu, GC_SECS_PER_RELU);
            assert_eq!(p.he_macs_per_sec, HE_MACS_PER_SEC);
        }
    }
}
