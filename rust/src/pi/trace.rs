//! Protocol-level Private-Inference trace (DELPHI-style hybrid).
//!
//! The analytic model in [`crate::pi::analytic`] prices a whole inference
//! with closed-form constants. This module instead *walks the protocol*:
//! it simulates the online phase of a DELPHI-like two-party hybrid (client
//! holds the input, server holds the weights) layer by layer over a real
//! (model, mask) pair, emitting the actual message sequence — sizes,
//! directions, rounds — so that schedule-level effects are visible:
//! a fully-linearized layer drops its GC round entirely, masked layers
//! shrink their GC payload proportionally, and the round count depends on
//! which layers still hold ReLUs (exactly what BCD changes).
//!
//! The walk itself is factored out as [`script`]: the ordered [`Step`]
//! sequence of one inference. [`simulate`] folds the script into a
//! [`Trace`] (this module's historical output), and the serving simulator
//! ([`crate::pi::serve`]) replays the *same* script per concurrent
//! request — which is what makes the per-direction byte totals of the two
//! conserved by construction (the `prop_invariants` contract).
//!
//! This is a *communication/cost* simulation, not a cryptographic
//! implementation: payload sizes follow the published DELPHI/GAZELLE
//! constants, and no secret data is involved.

use super::protocol::Protocol;
use super::{CostModel, InferenceCost};
use crate::model::Mask;
use crate::runtime::manifest::ModelInfo;

/// Direction of one simulated message.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dir {
    ClientToServer,
    ServerToClient,
}

/// One online-phase message.
#[derive(Clone, Debug)]
pub struct Message {
    pub layer: usize,
    pub dir: Dir,
    pub bytes: u64,
    pub what: &'static str,
}

/// Full online-phase trace of one private inference.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub messages: Vec<Message>,
    /// Communication rounds (direction changes / layer barriers).
    pub rounds: usize,
    /// Total garbled-circuit payload [bytes].
    pub gc_bytes: u64,
    /// Total share-transfer payload [bytes].
    pub share_bytes: u64,
    /// Local compute charged to GC evaluation [s].
    pub gc_compute_secs: f64,
    /// Local compute charged to linear layers under shares [s].
    pub linear_compute_secs: f64,
}

impl Trace {
    pub fn total_bytes(&self) -> u64 {
        self.gc_bytes + self.share_bytes
    }

    /// Client→server payload total [bytes].
    pub fn up_bytes(&self) -> u64 {
        self.dir_bytes(Dir::ClientToServer)
    }

    /// Server→client payload total [bytes].
    pub fn down_bytes(&self) -> u64 {
        self.dir_bytes(Dir::ServerToClient)
    }

    fn dir_bytes(&self, dir: Dir) -> u64 {
        self.messages.iter().filter(|m| m.dir == dir).map(|m| m.bytes).sum()
    }

    /// Rounds attributable to GC exchanges: total rounds minus the two
    /// endpoint transfers (input share up, logit share down). A fully
    /// linearized network therefore reports zero ReLU-phase rounds.
    pub fn relu_rounds(&self) -> usize {
        self.rounds.saturating_sub(2)
    }

    /// End-to-end online latency under a network model: serialized
    /// transfers + per-round RTTs + local compute.
    pub fn latency_secs(&self, proto: &Protocol) -> f64 {
        self.total_bytes() as f64 / proto.bandwidth
            + self.rounds as f64 * proto.rtt
            + self.gc_compute_secs
            + self.linear_compute_secs
    }

    fn push(&mut self, m: Message) {
        match m.what {
            "garbled ReLU tables" => self.gc_bytes += m.bytes,
            _ => self.share_bytes += m.bytes,
        }
        // A round per direction flip (the first message opens round 1).
        if self.messages.last().map(|prev| prev.dir != m.dir).unwrap_or(true) {
            self.rounds += 1;
        }
        self.messages.push(m);
    }
}

/// Per-element share width (DELPHI uses a 32-bit prime field).
pub const SHARE_BYTES: u64 = 4;

/// One step of the online phase, in protocol order.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Step {
    /// Client→server transfer.
    Up { layer: usize, bytes: u64, what: &'static str },
    /// Server→client transfer.
    Down { layer: usize, bytes: u64, what: &'static str },
    /// Server-side linear layer under shares — local compute, and the
    /// unit the serving simulator batches across clients.
    Linear { layer: usize, macs: f64 },
    /// Client-side GC evaluation of `relus` surviving ReLUs.
    GcEval { layer: usize, relus: u64 },
}

/// The ordered step sequence of one private inference (DELPHI online):
///
///   1. client sends its masked input share (once),
///   2. per linear layer: server evaluates under additive shares — local
///      compute only (preprocessing already exchanged the Beaver/HE state),
///   3. per activation layer with k > 0 ReLUs: one GC exchange —
///      server→client garbled tables for k ReLUs, client-side GC
///      evaluation, client→server the re-shared result (k field
///      elements). Linearized slots (identity or polynomial) stay inside
///      the share arithmetic: zero communication.
///   4. server sends the logit share back (once).
///
/// Both [`simulate`] and [`crate::pi::serve::serve`] replay this exact
/// sequence, so their byte/round accounting cannot drift apart.
pub fn script(info: &ModelInfo, mask: &Mask, proto: &Protocol) -> Vec<Step> {
    let hist = mask.layer_histogram(info);
    let mut steps = Vec::with_capacity(2 + 4 * info.mask_layers.len());

    let input_elems = (info.channels * info.image_size * info.image_size) as u64;
    steps.push(Step::Up { layer: 0, bytes: input_elems * SHARE_BYTES, what: "input share" });

    let mut walk = super::analytic::MacWalk::new(info);
    for (l, entry) in info.mask_layers.iter().enumerate() {
        steps.push(Step::Linear { layer: l, macs: walk.layer(&entry.shape) });
        let k = hist[l] as u64;
        if k > 0 {
            steps.push(Step::Down {
                layer: l,
                bytes: k * proto.gc_bytes_per_relu as u64,
                what: "garbled ReLU tables",
            });
            steps.push(Step::GcEval { layer: l, relus: k });
            steps.push(Step::Up {
                layer: l,
                bytes: k * SHARE_BYTES,
                what: "re-shared activations",
            });
        }
    }

    steps.push(Step::Down {
        layer: info.mask_layers.len(),
        bytes: info.num_classes as u64 * SHARE_BYTES,
        what: "logit share",
    });
    steps
}

/// Simulate the online phase for `mask` over `info`'s layer sequence by
/// folding [`script`] into a [`Trace`].
pub fn simulate(info: &ModelInfo, mask: &Mask, proto: &Protocol) -> Trace {
    let mut tr = Trace::default();
    for step in script(info, mask, proto) {
        match step {
            Step::Up { layer, bytes, what } => {
                tr.push(Message { layer, dir: Dir::ClientToServer, bytes, what })
            }
            Step::Down { layer, bytes, what } => {
                tr.push(Message { layer, dir: Dir::ServerToClient, bytes, what })
            }
            Step::Linear { macs, .. } => tr.linear_compute_secs += macs / proto.he_macs_per_sec,
            Step::GcEval { relus, .. } => {
                tr.gc_compute_secs += relus as f64 * proto.gc_secs_per_relu
            }
        }
    }
    tr
}

/// Side-by-side of the analytic estimate and the simulated trace — used by
/// tests and the `picost --simulate` CLI to keep the two models honest.
pub fn compare(info: &ModelInfo, mask: &Mask, proto: &Protocol) -> (f64, f64) {
    let analytic = super::analytic::estimate_state(info, mask, proto).total_secs;
    let simulated = simulate(info, mask, proto).latency_secs(proto);
    (analytic, simulated)
}

/// The message-walk model as a [`CostModel`].
pub struct TraceSim;

impl CostModel for TraceSim {
    fn name(&self) -> &'static str {
        "trace"
    }

    fn price(&self, info: &ModelInfo, mask: &Mask, proto: &Protocol) -> InferenceCost {
        let tr = simulate(info, mask, proto);
        let hist = mask.layer_histogram(info);
        InferenceCost {
            model: self.name(),
            protocol: proto.name,
            relus: mask.count(),
            active_layers: hist.iter().filter(|&&h| h > 0).count(),
            rounds: tr.rounds,
            up_bytes: tr.up_bytes(),
            down_bytes: tr.down_bytes(),
            latency_secs: tr.latency_secs(proto),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::protocol::{LAN, WAN};
    use super::super::Analytic;
    use super::*;
    use crate::runtime::manifest::PackEntry;

    fn fake_info() -> ModelInfo {
        ModelInfo {
            key: "m".into(),
            backbone: "resnet".into(),
            num_classes: 10,
            image_size: 8,
            channels: 3,
            poly: false,
            param_size: 1,
            mask_size: 192,
            mask_layers: vec![
                PackEntry { name: "a".into(), shape: vec![2, 8, 8], offset: 0, size: 128 },
                PackEntry { name: "b".into(), shape: vec![4, 4, 4], offset: 128, size: 64 },
            ],
            param_entries: vec![],
            artifacts: Default::default(),
        }
    }

    #[test]
    fn full_mask_trace_structure() {
        let info = fake_info();
        let tr = simulate(&info, &Mask::full(192), &LAN);
        // input + 2 x (tables + reshare) + logits = 6 messages.
        assert_eq!(tr.messages.len(), 6);
        assert_eq!(tr.gc_bytes, 192 * 2048);
        assert!(tr.rounds >= 4);
        assert_eq!(tr.relu_rounds(), tr.rounds - 2);
        assert!(tr.latency_secs(&LAN) > 0.0);
    }

    #[test]
    fn linearized_layer_drops_its_round() {
        let info = fake_info();
        let full = simulate(&info, &Mask::full(192), &LAN);
        let mut m = Mask::full(192);
        m.remove_layer(&info, 1);
        let cut = simulate(&info, &m, &LAN);
        assert_eq!(cut.messages.len(), full.messages.len() - 2);
        assert!(cut.rounds < full.rounds);
        assert_eq!(cut.gc_bytes, 128 * 2048);
        // Linear compute unchanged: convs still run under shares.
        assert!((cut.linear_compute_secs - full.linear_compute_secs).abs() < 1e-12);
    }

    #[test]
    fn gc_bytes_proportional_to_budget() {
        let info = fake_info();
        let mut m = Mask::full(192);
        let drop: Vec<usize> = (0..96).collect();
        m.apply_removal(&drop).unwrap();
        let tr = simulate(&info, &m, &WAN);
        assert_eq!(tr.gc_bytes, 96 * 2048);
    }

    #[test]
    fn simulation_agrees_with_analytic_model() {
        // Round accounting is aligned between the two models (2 flips per
        // GC layer + 2 endpoint transfers); residual differences are the
        // share-transfer bytes the analytic model folds into constants.
        let info = fake_info();
        for proto in [&LAN, &WAN] {
            for keep in [192usize, 100, 10] {
                let mut m = Mask::full(192);
                if keep < 192 {
                    let drop: Vec<usize> = (0..192 - keep).collect();
                    m.apply_removal(&drop).unwrap();
                }
                let (a, s) = compare(&info, &m, proto);
                let ratio = s / a;
                assert!(
                    (0.3..=3.0).contains(&ratio),
                    "{}@{keep}: analytic {a:.6}s vs sim {s:.6}s",
                    proto.name
                );
            }
        }
    }

    #[test]
    fn wan_latency_dominated_by_gc_traffic() {
        let info = fake_info();
        let tr = simulate(&info, &Mask::full(192), &WAN);
        let gc_time = tr.gc_bytes as f64 / WAN.bandwidth;
        assert!(gc_time > tr.share_bytes as f64 / WAN.bandwidth);
    }

    #[test]
    fn cost_models_agree_on_bytes_and_rounds() {
        // The CostModel contract: analytic and trace agree exactly on the
        // count-valued fields; only latency composition differs.
        let info = fake_info();
        for keep in [192usize, 128, 64, 1] {
            let mut m = Mask::full(192);
            if keep < 192 {
                let drop: Vec<usize> = (0..192 - keep).collect();
                m.apply_removal(&drop).unwrap();
            }
            for proto in [&LAN, &WAN] {
                let a = Analytic.price(&info, &m, proto);
                let t = TraceSim.price(&info, &m, proto);
                assert_eq!(a.relus, t.relus);
                assert_eq!(a.active_layers, t.active_layers);
                assert_eq!(a.rounds, t.rounds);
                assert_eq!(a.up_bytes, t.up_bytes);
                assert_eq!(a.down_bytes, t.down_bytes);
            }
        }
    }
}
