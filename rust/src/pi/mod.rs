//! `pi::` — the unified Private-Inference cost/protocol API (DESIGN.md §14).
//!
//! PR 9 consolidated the two overlapping PI surfaces that grew up
//! separately — the closed-form estimator (`picost`) and the
//! message-level protocol walk (`protosim`) — into one module tree and
//! added the fleet-scale serving simulator on top:
//!
//! | Path | What it prices | Entry points |
//! |------|----------------|--------------|
//! | [`protocol`] | deployment scenarios | [`Protocol`], [`find`], [`registry`] |
//! | [`analytic`] | one inference, closed form | [`estimate_state`], [`Analytic`] |
//! | [`trace`]    | one inference, message walk | [`simulate`], [`compare`], [`TraceSim`] |
//! | [`serve`]    | a fleet of inferences | [`serve::serve`], [`ServeConfig`], [`ServeReport`] |
//!
//! The per-inference models share one typed entry point, the
//! [`CostModel`] trait: `price(info, mask, protocol)` returns an
//! [`InferenceCost`] whose count-valued fields (ReLUs, active layers,
//! rounds, per-direction bytes) are **identical across models by
//! construction** — both reduce to [`trace::script`]'s closed forms —
//! while the latency composition is each model's own. The serving
//! simulator replays the same script per concurrent request, which is
//! what the `prop_invariants` byte-conservation property pins down.
//!
//! The old `crate::picost` / `crate::protosim` paths still compile as
//! deprecated shims re-exporting from here; new code should use `pi::`.

pub mod analytic;
pub mod protocol;
pub mod serve;
pub mod trace;

pub use analytic::{estimate, estimate_macs, estimate_state, Analytic, CostReport};
pub use protocol::{find, names, registry, Protocol, LAN, MOBILE, WAN};
pub use serve::{ServeConfig, ServeReport};
pub use trace::{compare, simulate, Dir, Message, Trace, TraceSim, SHARE_BYTES};

use crate::model::Mask;
use crate::runtime::manifest::ModelInfo;

/// One priced private inference — the common currency of every
/// [`CostModel`]. Count-valued fields agree exactly across models;
/// `latency_secs` is each model's own composition.
#[derive(Clone, Debug, PartialEq)]
pub struct InferenceCost {
    /// Which model priced it ("analytic", "trace").
    pub model: &'static str,
    pub protocol: &'static str,
    pub relus: usize,
    pub active_layers: usize,
    /// Online communication rounds (`2 * active_layers + 2`).
    pub rounds: usize,
    /// Client→server payload [bytes].
    pub up_bytes: u64,
    /// Server→client payload [bytes].
    pub down_bytes: u64,
    pub latency_secs: f64,
}

/// A per-inference PI cost model: price one (model, mask) pair under one
/// [`Protocol`]. Implemented by [`Analytic`] (closed form) and
/// [`TraceSim`] (message walk); the CLI's `picost`/`serve` tables print
/// both side by side to keep them honest.
pub trait CostModel {
    fn name(&self) -> &'static str;
    fn price(&self, info: &ModelInfo, mask: &Mask, proto: &Protocol) -> InferenceCost;
}

/// Every registered per-inference cost model, for side-by-side tables.
pub fn cost_models() -> [&'static dyn CostModel; 2] {
    [&Analytic, &TraceSim]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cost_model_registry_names() {
        let names: Vec<&str> = cost_models().iter().map(|m| m.name()).collect();
        assert_eq!(names, ["analytic", "trace"]);
    }
}
