//! Content-addressed artifact store (DESIGN.md §15).
//!
//! A digest-keyed blob store backing distributed runs: checkpoints, zoo
//! stages and per-sweep model parameters are stored once under their
//! FNV-1a-256 digest, so remote workers cold-start by digest instead of
//! shipping state in-band, and identical content is never stored twice.
//!
//! Layout under the store root:
//!
//! ```text
//! <root>/objects/<digest[..2]>/<digest>     # one file per blob
//! ```
//!
//! The digest is streamed over content on **write and read**: [`CasStore::put`]
//! hashes while copying into a temp file and renames into place only when the
//! digest is known (atomic, idempotent), and [`CasStore::get`] re-hashes the
//! object while reading and rejects any content whose digest no longer
//! matches its name — a tampered or bit-rotted blob can never be served.
//! Run manifests carry `BlobRef` provenance ([`crate::runstore`]), and
//! `cdnl runs gc` treats every blob referenced by a surviving manifest as
//! live (never collected).
//!
//! The hash is FNV-1a with 256-bit parameters (prime `2^168 + 2^8 + 0x63`),
//! implemented over four u64 limbs with basic integer arithmetic — the same
//! dependency-free idiom as the crate's 64-bit config fingerprint
//! ([`crate::config::fingerprint_pairs`]), scaled up so accidental
//! collisions are out of the question at fleet scale. Digests print as 64
//! lowercase hex characters, most-significant limb first.

use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeSet;
use std::io::{Read, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

/// Streaming FNV-1a-256 hasher (four little-endian u64 limbs).
#[derive(Clone, Debug)]
pub struct Fnv256 {
    h: [u64; 4],
}

/// FNV-1a-256 offset basis (big-endian hex
/// `dd268dbcaac550362d98c384c4e576ccc8b1536847b6bbb31023b4c8caee0535`),
/// as little-endian limbs.
const OFFSET_BASIS: [u64; 4] =
    [0x1023b4c8caee0535, 0xc8b1536847b6bbb3, 0x2d98c384c4e576cc, 0xdd268dbcaac55036];

/// Low 64 bits of the 256-bit FNV prime `2^168 + 2^8 + 0x63`; the only
/// other set bit is bit 168, handled as a limb shift in [`Fnv256::mul_prime`].
const PRIME_LOW: u64 = 0x163;

impl Default for Fnv256 {
    fn default() -> Self {
        Self::new()
    }
}

impl Fnv256 {
    pub fn new() -> Fnv256 {
        Fnv256 { h: OFFSET_BASIS }
    }

    /// `h <- h * (2^168 + 0x163) mod 2^256`: a 168-bit limb shift plus a
    /// small-constant multiply, combined with carrying adds.
    fn mul_prime(&mut self) {
        let h = self.h;
        // h * 0x163 (mod 2^256), carried through the limbs.
        let mut lo = [0u64; 4];
        let mut carry: u128 = 0;
        for i in 0..4 {
            let t = h[i] as u128 * PRIME_LOW as u128 + carry;
            lo[i] = t as u64;
            carry = t >> 64;
        }
        // h << 168 (mod 2^256): 168 = 2 limbs + 40 bits.
        let sh = [0u64, 0, h[0] << 40, (h[1] << 40) | (h[0] >> 24)];
        // Sum the partial products.
        let mut out = [0u64; 4];
        let mut c: u128 = 0;
        for i in 0..4 {
            let t = lo[i] as u128 + sh[i] as u128 + c;
            out[i] = t as u64;
            c = t >> 64;
        }
        self.h = out;
    }

    pub fn update(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.h[0] ^= b as u64;
            self.mul_prime();
        }
    }

    /// 64-hex-char digest, most-significant limb first.
    pub fn hex(&self) -> String {
        format!("{:016x}{:016x}{:016x}{:016x}", self.h[3], self.h[2], self.h[1], self.h[0])
    }
}

/// One-shot digest of a byte slice.
pub fn digest_hex(bytes: &[u8]) -> String {
    let mut h = Fnv256::new();
    h.update(bytes);
    h.hex()
}

/// True iff `s` is a well-formed digest: exactly 64 lowercase hex chars.
/// Everything that touches the filesystem or the HTTP `/cas/<digest>`
/// endpoint validates with this first (no path traversal by construction).
pub fn valid_digest(s: &str) -> bool {
    s.len() == 64 && s.bytes().all(|b| b.is_ascii_digit() || (b'a'..=b'f').contains(&b))
}

/// Outcome of a [`CasStore::put`]: the content digest, the blob size, and
/// whether the store already held identical content (idempotent puts).
#[derive(Clone, Debug, PartialEq)]
pub struct PutOutcome {
    pub digest: String,
    pub bytes: u64,
    pub existed: bool,
}

/// Monotonic counter distinguishing concurrent temp files within a process.
static TEMP_SEQ: AtomicU64 = AtomicU64::new(0);

/// A digest-keyed blob store rooted at one directory.
pub struct CasStore {
    root: PathBuf,
}

impl CasStore {
    /// Open (or lazily create) a store rooted at `root`.
    pub fn open(root: impl Into<PathBuf>) -> CasStore {
        CasStore { root: root.into() }
    }

    /// The conventional per-experiment store: `<out_dir>/cas`, sibling of
    /// the run-store's `<out_dir>/runs`.
    pub fn for_experiment(exp: &crate::config::Experiment) -> CasStore {
        CasStore::open(PathBuf::from(&exp.out_dir).join("cas"))
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    fn object_path(&self, digest: &str) -> PathBuf {
        self.root.join("objects").join(&digest[..2]).join(digest)
    }

    /// Store the contents of `reader`, hashing while copying. The blob is
    /// written to a temp file and renamed under its digest only once the
    /// digest is known, so readers never observe partial objects and
    /// re-putting identical content is a no-op.
    pub fn put(&self, reader: &mut dyn Read) -> Result<PutOutcome> {
        let tmp_dir = self.root.join("objects");
        std::fs::create_dir_all(&tmp_dir)
            .with_context(|| format!("cas: create {tmp_dir:?}"))?;
        let tmp = tmp_dir.join(format!(
            ".tmp-{}-{}",
            std::process::id(),
            TEMP_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        let mut hasher = Fnv256::new();
        let mut total = 0u64;
        let write = (|| -> Result<()> {
            let mut f = std::fs::File::create(&tmp)?;
            let mut buf = [0u8; 64 * 1024];
            loop {
                let n = reader.read(&mut buf)?;
                if n == 0 {
                    break;
                }
                hasher.update(&buf[..n]);
                f.write_all(&buf[..n])?;
                total += n as u64;
            }
            f.sync_all()?;
            Ok(())
        })();
        if let Err(e) = write {
            let _ = std::fs::remove_file(&tmp);
            return Err(e.context("cas: staging blob"));
        }
        let digest = hasher.hex();
        let dest = self.object_path(&digest);
        if dest.exists() {
            let _ = std::fs::remove_file(&tmp);
            return Ok(PutOutcome { digest, bytes: total, existed: true });
        }
        std::fs::create_dir_all(dest.parent().expect("object path has a parent"))?;
        std::fs::rename(&tmp, &dest).with_context(|| format!("cas: commit {dest:?}"))?;
        Ok(PutOutcome { digest, bytes: total, existed: false })
    }

    /// [`Self::put`] over an in-memory blob.
    pub fn put_bytes(&self, bytes: &[u8]) -> Result<PutOutcome> {
        self.put(&mut std::io::Cursor::new(bytes))
    }

    /// [`Self::put`] over a file's contents (streamed, never fully buffered).
    pub fn put_file(&self, path: &Path) -> Result<PutOutcome> {
        let mut f =
            std::fs::File::open(path).with_context(|| format!("cas: put {path:?}"))?;
        self.put(&mut f)
    }

    pub fn contains(&self, digest: &str) -> bool {
        valid_digest(digest) && self.object_path(digest).exists()
    }

    /// Read a blob back, re-hashing while reading; content whose digest no
    /// longer matches its name is rejected, never returned.
    pub fn get(&self, digest: &str) -> Result<Vec<u8>> {
        if !valid_digest(digest) {
            bail!("cas: malformed digest {digest:?} (want 64 lowercase hex chars)");
        }
        let path = self.object_path(digest);
        let mut f = std::fs::File::open(&path)
            .map_err(|e| anyhow!("cas: no object {digest}: {e}"))?;
        let mut hasher = Fnv256::new();
        let mut out = Vec::new();
        let mut buf = [0u8; 64 * 1024];
        loop {
            let n = f.read(&mut buf)?;
            if n == 0 {
                break;
            }
            hasher.update(&buf[..n]);
            out.extend_from_slice(&buf[..n]);
        }
        let got = hasher.hex();
        if got != digest {
            bail!("cas: object {digest} failed verification (content hashes to {got}) — tampered or corrupt");
        }
        Ok(out)
    }

    /// Verify one object without materializing it for a caller: Ok(true) if
    /// present and intact, Ok(false) if absent, Err on digest mismatch.
    pub fn verify(&self, digest: &str) -> Result<bool> {
        if !self.contains(digest) {
            return Ok(false);
        }
        self.get(digest).map(|_| true)
    }

    /// Every digest currently stored, sorted.
    pub fn list(&self) -> Result<Vec<String>> {
        let objects = self.root.join("objects");
        let mut out = Vec::new();
        let Ok(shards) = std::fs::read_dir(&objects) else {
            return Ok(out); // empty/unborn store
        };
        for shard in shards {
            let shard = shard?;
            if !shard.file_type()?.is_dir() {
                continue; // stray temp file at the objects root
            }
            for obj in std::fs::read_dir(shard.path())? {
                let name = obj?.file_name().to_string_lossy().into_owned();
                if valid_digest(&name) {
                    out.push(name);
                }
            }
        }
        out.sort_unstable();
        Ok(out)
    }

    /// Collect every blob not in `live`. Returns the doomed digests; with
    /// `dry_run` nothing is deleted (the `gc --dry-run` preview contract —
    /// the returned set is exactly what a real pass would remove).
    pub fn gc(&self, live: &BTreeSet<String>, dry_run: bool) -> Result<Vec<String>> {
        let doomed: Vec<String> =
            self.list()?.into_iter().filter(|d| !live.contains(d)).collect();
        if !dry_run {
            for d in &doomed {
                let p = self.object_path(d);
                std::fs::remove_file(&p).with_context(|| format!("cas: gc {p:?}"))?;
            }
        }
        Ok(doomed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scratch(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("cdnl_cas_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn digest_shape_and_sensitivity() {
        let a = digest_hex(b"hello");
        let b = digest_hex(b"hellp");
        assert!(valid_digest(&a), "digest must be 64 lowercase hex: {a}");
        assert_ne!(a, b, "one-bit input change must move the digest");
        assert_eq!(a, digest_hex(b"hello"), "digest is deterministic");
        // Streaming == one-shot.
        let mut h = Fnv256::new();
        h.update(b"he");
        h.update(b"llo");
        assert_eq!(h.hex(), a);
        // Empty input hashes to the offset basis.
        assert_eq!(
            digest_hex(b""),
            "dd268dbcaac550362d98c384c4e576ccc8b1536847b6bbb31023b4c8caee0535"
        );
    }

    #[test]
    fn digest_validation() {
        assert!(valid_digest(&"a".repeat(64)));
        assert!(!valid_digest(&"a".repeat(63)));
        assert!(!valid_digest(&"A".repeat(64)), "uppercase rejected");
        assert!(!valid_digest(&"g".repeat(64)), "non-hex rejected");
        assert!(!valid_digest("../escape"), "traversal rejected");
    }

    #[test]
    fn put_get_roundtrip_and_idempotent_puts() {
        let store = CasStore::open(scratch("roundtrip"));
        let blob = b"the quick brown fox".to_vec();
        let put = store.put_bytes(&blob).unwrap();
        assert!(!put.existed);
        assert_eq!(put.bytes, blob.len() as u64);
        assert_eq!(put.digest, digest_hex(&blob));
        // Idempotent re-put.
        let again = store.put_bytes(&blob).unwrap();
        assert!(again.existed);
        assert_eq!(again.digest, put.digest);
        // Round trip, verified on read.
        assert!(store.contains(&put.digest));
        assert_eq!(store.get(&put.digest).unwrap(), blob);
        assert_eq!(store.verify(&put.digest).unwrap(), true);
        assert_eq!(store.verify(&digest_hex(b"absent")).unwrap(), false);
        let _ = std::fs::remove_dir_all(store.root());
    }

    #[test]
    fn tampered_object_is_rejected_on_read() {
        let store = CasStore::open(scratch("tamper"));
        let put = store.put_bytes(b"payload to corrupt").unwrap();
        let path = store.object_path(&put.digest);
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[0] ^= 0x01;
        std::fs::write(&path, &bytes).unwrap();
        let err = store.get(&put.digest).unwrap_err().to_string();
        assert!(err.contains("failed verification"), "got: {err}");
        assert!(store.verify(&put.digest).is_err());
        let _ = std::fs::remove_dir_all(store.root());
    }

    #[test]
    fn gc_spares_live_and_previews_exactly() {
        let store = CasStore::open(scratch("gc"));
        let a = store.put_bytes(b"live blob").unwrap().digest;
        let b = store.put_bytes(b"dead blob").unwrap().digest;
        let live: BTreeSet<String> = [a.clone()].into_iter().collect();
        // Dry run previews without deleting.
        let preview = store.gc(&live, true).unwrap();
        assert_eq!(preview, vec![b.clone()]);
        assert!(store.contains(&b), "dry run must not delete");
        // Real pass removes exactly the preview.
        let removed = store.gc(&live, false).unwrap();
        assert_eq!(removed, preview);
        assert!(!store.contains(&b));
        assert!(store.contains(&a), "live blob survives");
        assert_eq!(store.list().unwrap(), vec![a]);
        let _ = std::fs::remove_dir_all(store.root());
    }
}
