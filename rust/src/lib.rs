//! # CDNL — Coordinate Descent for Network Linearization
//!
//! Production reproduction of "Coordinate Descent for Network Linearization"
//! (Rakhlin, Jevnisek, Avidan; AAAI 2025): Block Coordinate Descent over
//! binary ReLU masks for efficient Private Inference, plus every baseline
//! the paper compares against (SNL, AutoReP, SENet, DeepReDuce).
//!
//! Three-layer architecture — described in depth in `DESIGN.md` at the
//! repository root (section references like "DESIGN.md §0" throughout this
//! crate point there):
//! - **L3 (this crate)** — the rust coordinator: BCD optimizer, baselines,
//!   PI cost model, experiment launcher, metrics. Owns the event loop. The
//!   BCD hypothesis scan fans out across a thread pool with a deterministic
//!   merge ([`coordinator::trials`]): identical results at any worker count.
//! - **L2 — the [`runtime::Backend`] trait** — pluggable execution of the
//!   model entry points behind opaque device-buffer handles, plus an
//!   optional segmented forward API for staged trial execution: backends
//!   that know their layer structure resume a hypothesis's forward pass
//!   from cached prefix activations, bit-identically to a full forward
//!   (DESIGN.md §8; the PJRT engine gracefully stays on full forwards).
//!   Two implementations ship: the PJRT engine over AOT HLO artifacts
//!   (`--features pjrt`; JAX lowers `python/compile/model.py` once via
//!   `make artifacts`, Python never runs on the request path) and the
//!   pure-Rust [`runtime::RefBackend`] reference backend (masked-
//!   activation MLPs plus ResNet18/WRN-22-style convolutional residual
//!   topologies with per-channel masks, all hand-written autodiff pinned
//!   by a finite-difference battery — DESIGN.md §12) so the whole
//!   coordinator runs — tests, CI, benches — with no artifacts or native
//!   deps.
//! - **L1** — Pallas masked-activation kernels (`python/compile/kernels/`),
//!   correctness-checked against a pure-jnp oracle (PJRT path only).
//!
//! Every linearization method (SNL, AutoReP, SENet, DeepReDuce and BCD
//! itself) registers in [`methods::registry`] behind the
//! [`methods::Method`] trait: one typed `run(ctx, state, budget) ->
//! MethodOutcome` entry point with per-method config slices of
//! [`config::Experiment`] and chainable stages (`cdnl run snl+bcd`) —
//! DESIGN.md §10.
//!
//! Long-lived runs are durable: the [`runstore`] gives every experiment a
//! directory with a versioned serde-backed `run.json` manifest (config
//! fingerprint, stage provenance, per-sweep BCD trace, RNG resume cursor),
//! written atomically after every sweep, so an interrupted `run_bcd`
//! resumes bit-identically via `cdnl runs resume <id>`.
//!
//! Backends are `Send + Sync`; [`runtime::open_backend`] picks one by name
//! or automatically (`auto`: PJRT when compiled in and artifacts exist,
//! else reference).
//!
//! Benchmarks are first-class: the [`bench`] registry unifies the paper's
//! table/figure grid, the §Perf microbenchmarks, a CI smoke tier and the
//! PI serving tier behind `cdnl bench list|run|compare`, each run
//! emitting a typed `BENCH_<name>.json` report that a comparator gates
//! against committed baselines (DESIGN.md §9).
//!
//! The Private-Inference cost surface is unified under [`pi`]
//! (DESIGN.md §14): a named [`pi::Protocol`] registry (LAN/WAN/MOBILE),
//! the [`pi::CostModel`] trait over the closed-form and message-walk
//! per-inference models, and the deterministic fleet-scale serving
//! simulator [`pi::serve`] behind `cdnl serve` and the `serve` bench
//! tier. The pre-PR-9 [`picost`]/[`protosim`] paths remain as deprecated
//! shims.
//!
//! The scan also scales past one machine (DESIGN.md §15): [`dist`] is a
//! dependency-free HTTP coordinator/worker protocol (leased slab claims,
//! idempotent completions, the same sequential replay merge — so the
//! outcome stays bit-identical for any worker membership), and [`cas`] is
//! the content-addressed blob store workers cold-start from (digest-keyed
//! params/checkpoints, streaming FNV-256 verification on write and read).
//! `cdnl coordinate --listen` / `cdnl worker --connect` drive them; run
//! manifests carry blob-digest provenance so `cdnl runs gc` never collects
//! a referenced blob.

pub mod bench;
pub mod cas;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod dist;
pub mod metrics;
pub mod methods;
pub mod model;
pub mod pi;
pub mod picost;
pub mod pipeline;
pub mod protosim;
pub mod runstore;
pub mod runtime;
pub mod tensor;
pub mod util;

pub use config::Experiment;
pub use runtime::{open_backend, open_backend_with, Backend, RefBackend};

#[cfg(feature = "pjrt")]
pub use runtime::engine::Engine;
