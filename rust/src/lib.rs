//! # CDNL — Coordinate Descent for Network Linearization
//!
//! Production reproduction of "Coordinate Descent for Network Linearization"
//! (Rakhlin, Jevnisek, Avidan; AAAI 2025): Block Coordinate Descent over
//! binary ReLU masks for efficient Private Inference, plus every baseline
//! the paper compares against (SNL, AutoReP, SENet, DeepReDuce).
//!
//! Three-layer architecture (DESIGN.md):
//! - **L3 (this crate)** — the rust coordinator: BCD optimizer, baselines,
//!   PI cost model, experiment launcher, metrics. Owns the event loop.
//! - **L2** — JAX model (`python/compile/model.py`), lowered once to HLO
//!   text by `make artifacts`; Python never runs on the request path.
//! - **L1** — Pallas masked-activation kernels (`python/compile/kernels/`),
//!   correctness-checked against a pure-jnp oracle.
//!
//! The [`runtime`] module bridges L3 to the AOT artifacts via the `xla`
//! crate's PJRT CPU client.

pub mod config;
pub mod coordinator;
pub mod data;
pub mod metrics;
pub mod methods;
pub mod model;
pub mod picost;
pub mod pipeline;
pub mod protosim;
pub mod runtime;
pub mod tensor;
pub mod util;

pub use config::Experiment;
pub use runtime::engine::Engine;
