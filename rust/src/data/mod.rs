//! Datasets: procedural SynthVision generators + batching.
//!
//! The paper evaluates on CIFAR-10/CIFAR-100/TinyImageNet; those downloads
//! are unavailable offline, so `synth` builds class-conditional procedural
//! image datasets with the same role (DESIGN.md §0/§3): classification
//! tasks whose accuracy degrades smoothly as ReLUs are removed.

pub mod synth;

use crate::tensor::{Tensor, TensorI32};
use crate::util::prng::Rng;

/// An in-memory labelled image dataset (NCHW f32).
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub num_classes: usize,
    pub channels: usize,
    pub image_size: usize,
    /// Flattened images, `n * c * h * w`.
    pub images: Vec<f32>,
    pub labels: Vec<i32>,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    fn image_elems(&self) -> usize {
        self.channels * self.image_size * self.image_size
    }

    /// Assemble a batch from explicit indices (wrapping copies allowed).
    pub fn gather(&self, idxs: &[usize]) -> (Tensor, TensorI32) {
        let ie = self.image_elems();
        let mut xs = Vec::with_capacity(idxs.len() * ie);
        let mut ys = Vec::with_capacity(idxs.len());
        for &i in idxs {
            xs.extend_from_slice(&self.images[i * ie..(i + 1) * ie]);
            ys.push(self.labels[i]);
        }
        (
            Tensor::new(
                vec![idxs.len(), self.channels, self.image_size, self.image_size],
                xs,
            ),
            TensorI32::new(vec![idxs.len()], ys),
        )
    }

    /// Deterministic contiguous batch starting at `start`, wrapping around.
    pub fn batch_at(&self, start: usize, batch: usize) -> (Tensor, TensorI32) {
        let idxs: Vec<usize> = (0..batch).map(|i| (start + i) % self.len()).collect();
        self.gather(&idxs)
    }

    /// Count of examples per class (sanity/test helper).
    pub fn class_histogram(&self) -> Vec<usize> {
        let mut h = vec![0usize; self.num_classes];
        for &y in &self.labels {
            h[y as usize] += 1;
        }
        h
    }
}

/// Epoch iterator over shuffled fixed-size batches (wrap-padded tail).
pub struct Batcher<'a> {
    ds: &'a Dataset,
    order: Vec<usize>,
    pos: usize,
    batch: usize,
}

impl<'a> Batcher<'a> {
    pub fn new(ds: &'a Dataset, batch: usize, rng: &mut Rng) -> Self {
        let mut order: Vec<usize> = (0..ds.len()).collect();
        rng.shuffle(&mut order);
        Self { ds, order, pos: 0, batch }
    }

    /// Next batch; reshuffles and restarts when the epoch is exhausted.
    pub fn next_batch(&mut self, rng: &mut Rng) -> (Tensor, TensorI32) {
        if self.pos + self.batch > self.order.len() {
            rng.shuffle(&mut self.order);
            self.pos = 0;
        }
        let idxs = &self.order[self.pos..self.pos + self.batch];
        self.pos += self.batch;
        self.ds.gather(idxs)
    }

    /// Batches consumed so far in the current epoch.
    pub fn epoch_pos(&self) -> usize {
        self.pos / self.batch.max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        Dataset {
            name: "t".into(),
            num_classes: 2,
            channels: 1,
            image_size: 2,
            images: (0..10 * 4).map(|i| i as f32).collect(),
            labels: (0..10).map(|i| (i % 2) as i32).collect(),
        }
    }

    #[test]
    fn gather_shapes() {
        let ds = tiny();
        let (x, y) = ds.gather(&[0, 3, 7]);
        assert_eq!(x.shape, vec![3, 1, 2, 2]);
        assert_eq!(y.data, vec![0, 1, 1]);
    }

    #[test]
    fn batch_wraps() {
        let ds = tiny();
        let (x, y) = ds.batch_at(8, 4);
        assert_eq!(x.shape[0], 4);
        assert_eq!(y.data, vec![0, 1, 0, 1]); // 8, 9, 0, 1
    }

    #[test]
    fn batcher_covers_epoch() {
        let ds = tiny();
        let mut rng = Rng::new(0);
        let mut b = Batcher::new(&ds, 5, &mut rng);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..2 {
            let (x, _) = b.next_batch(&mut rng);
            // Identify samples by their first pixel (unique per sample).
            for i in 0..5 {
                seen.insert(x.data[i * 4] as usize / 4);
            }
        }
        assert_eq!(seen.len(), 10);
    }

    #[test]
    fn histogram() {
        assert_eq!(tiny().class_histogram(), vec![5, 5]);
    }
}
