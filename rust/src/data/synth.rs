//! SynthVision: procedural class-conditional image datasets.
//!
//! Stand-ins for CIFAR-10 / CIFAR-100 / TinyImageNet (offline environment —
//! DESIGN.md §0). Each class has a *signature* drawn deterministically from
//! the dataset seed: a Gabor texture (orientation + frequency), one or two
//! geometric sprites (shape, size, position prior) and an RGB color prior.
//! Instances add pose/position jitter and pixel noise, so class evidence is
//! carried by spatially-localized nonlinear features — exactly the regime
//! where removing ReLUs hurts and where their placement matters (the paper's
//! Figure 7 layer-distribution phenomenon).
//!
//! Layout contract: images are channel-planar NCHW — pixel `(c, y, x)` of an
//! example lives at `c*s*s + y*s + x`. The conv reference backend (DESIGN.md
//! §12) indexes stem inputs with exactly this formula, so the contract is
//! pinned by a test below rather than implied.

use super::Dataset;
use crate::util::prng::Rng;

/// Recipe for one SynthVision dataset.
#[derive(Clone, Debug)]
pub struct SynthSpec {
    pub name: &'static str,
    pub num_classes: usize,
    pub image_size: usize,
    pub train_n: usize,
    pub test_n: usize,
    pub seed: u64,
    /// Instance pixel-noise stddev; higher = harder task.
    pub noise: f32,
}

/// The three benchmark datasets (DESIGN.md §3).
pub const SYNTH10: SynthSpec = SynthSpec {
    name: "synth10",
    num_classes: 10,
    image_size: 16,
    train_n: 4096,
    test_n: 1024,
    seed: 0x5EED_0010,
    noise: 0.35,
};

pub const SYNTH100: SynthSpec = SynthSpec {
    name: "synth100",
    num_classes: 20,
    image_size: 16,
    train_n: 4096,
    test_n: 1024,
    seed: 0x5EED_0100,
    noise: 0.40,
};

pub const SYNTHTINY: SynthSpec = SynthSpec {
    name: "synthtiny",
    num_classes: 20,
    image_size: 32,
    train_n: 2048,
    test_n: 512,
    seed: 0x5EED_1111,
    noise: 0.45,
};

pub fn by_name(name: &str) -> Option<&'static SynthSpec> {
    match name {
        "synth10" => Some(&SYNTH10),
        "synth100" => Some(&SYNTH100),
        "synthtiny" => Some(&SYNTHTINY),
        _ => None,
    }
}

/// Per-class generative signature.
#[derive(Clone, Debug)]
struct ClassSig {
    gabor_theta: f32,
    gabor_freq: f32,
    gabor_amp: f32,
    color: [f32; 3],
    sprites: Vec<SpriteSig>,
}

#[derive(Clone, Debug)]
struct SpriteSig {
    kind: u8, // 0 square, 1 disc, 2 cross, 3 ring
    cx: f32,  // position prior in [0,1]
    cy: f32,
    radius: f32, // fraction of image size
    polarity: f32,
}

fn class_signature(rng: &mut Rng, class: usize, num_classes: usize) -> ClassSig {
    // Orientation is evenly spread over classes with per-class jitter so
    // texture alone separates classes only partially — sprites are needed
    // for full separation, making the task genuinely compositional.
    let base_theta = std::f32::consts::PI * class as f32 / num_classes as f32;
    let n_sprites = 1 + (class % 2);
    let sprites = (0..n_sprites)
        .map(|_| SpriteSig {
            kind: (rng.below(4)) as u8,
            cx: rng.range_f32(0.2, 0.8),
            cy: rng.range_f32(0.2, 0.8),
            radius: rng.range_f32(0.12, 0.28),
            polarity: if rng.f32() < 0.5 { 1.0 } else { -1.0 },
        })
        .collect();
    ClassSig {
        gabor_theta: base_theta + rng.range_f32(-0.1, 0.1),
        gabor_freq: rng.range_f32(1.5, 4.0),
        gabor_amp: rng.range_f32(0.5, 0.9),
        color: [rng.range_f32(-1.0, 1.0), rng.range_f32(-1.0, 1.0), rng.range_f32(-1.0, 1.0)],
        sprites,
    }
}

/// Render one instance of `sig` into `out` (3 x s x s, row-major).
fn render(
    sig: &ClassSig,
    s: usize,
    rng: &mut Rng,
    noise: f32,
    out: &mut [f32],
) {
    let sf = s as f32;
    // Instance jitter: texture phase, sprite offsets, global brightness.
    let phase = rng.range_f32(0.0, std::f32::consts::TAU);
    let theta = sig.gabor_theta + rng.range_f32(-0.15, 0.15);
    let (sin_t, cos_t) = theta.sin_cos();
    let bright = rng.range_f32(0.8, 1.2);
    let jitter: Vec<(f32, f32)> = sig
        .sprites
        .iter()
        .map(|_| (rng.range_f32(-0.08, 0.08), rng.range_f32(-0.08, 0.08)))
        .collect();

    for y in 0..s {
        for x in 0..s {
            let u = x as f32 / sf;
            let v = y as f32 / sf;
            // Oriented Gabor-ish carrier.
            let t = (u * cos_t + v * sin_t) * sig.gabor_freq * std::f32::consts::TAU + phase;
            let tex = sig.gabor_amp * t.sin();
            // Sprites: additive bumps with crisp (nonlinear) edges.
            let mut sprite_v = 0.0f32;
            for (sp, &(jx, jy)) in sig.sprites.iter().zip(&jitter) {
                let dx = u - (sp.cx + jx);
                let dy = v - (sp.cy + jy);
                let r = sp.radius;
                let inside = match sp.kind {
                    0 => dx.abs() < r && dy.abs() < r,
                    1 => dx * dx + dy * dy < r * r,
                    2 => dx.abs() < r * 0.35 || dy.abs() < r * 0.35,
                    _ => {
                        let d2 = dx * dx + dy * dy;
                        d2 < r * r && d2 > (r * 0.55) * (r * 0.55)
                    }
                };
                if inside {
                    sprite_v += sp.polarity;
                }
            }
            let base = (tex + 1.5 * sprite_v) * bright;
            for c in 0..3 {
                let val = base * (1.0 + 0.5 * sig.color[c]) + 0.3 * sig.color[c]
                    + noise * rng.normal();
                out[c * s * s + y * s + x] = val.clamp(-3.0, 3.0);
            }
        }
    }
}

/// Generate the (train, test) pair for a spec. Deterministic in the seed;
/// train and test draw from the same class signatures but disjoint RNG
/// streams (true held-out instances).
pub fn generate(spec: &SynthSpec) -> (Dataset, Dataset) {
    let mut root = Rng::new(spec.seed);
    let mut sig_rng = root.fork(1);
    let sigs: Vec<ClassSig> = (0..spec.num_classes)
        .map(|c| class_signature(&mut sig_rng, c, spec.num_classes))
        .collect();

    let make = |n: usize, rng: &mut Rng| -> Dataset {
        let s = spec.image_size;
        let ie = 3 * s * s;
        let mut images = vec![0.0f32; n * ie];
        let mut labels = Vec::with_capacity(n);
        for i in 0..n {
            let class = i % spec.num_classes; // balanced by construction
            render(
                &sigs[class],
                s,
                rng,
                spec.noise,
                &mut images[i * ie..(i + 1) * ie],
            );
            labels.push(class as i32);
        }
        Dataset {
            name: spec.name.to_string(),
            num_classes: spec.num_classes,
            channels: 3,
            image_size: s,
            images,
            labels,
        }
    };

    let mut train_rng = root.fork(2);
    let mut test_rng = root.fork(3);
    (make(spec.train_n, &mut train_rng), make(spec.test_n, &mut test_rng))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let (a, _) = generate(&SynthSpec { train_n: 32, test_n: 8, ..SYNTH10 });
        let (b, _) = generate(&SynthSpec { train_n: 32, test_n: 8, ..SYNTH10 });
        assert_eq!(a.images, b.images);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn balanced_classes() {
        let (tr, te) = generate(&SynthSpec { train_n: 100, test_n: 40, ..SYNTH10 });
        assert!(tr.class_histogram().iter().all(|&c| c == 10));
        assert!(te.class_histogram().iter().all(|&c| c == 4));
    }

    #[test]
    fn train_test_disjoint_instances() {
        let (tr, te) = generate(&SynthSpec { train_n: 10, test_n: 10, ..SYNTH10 });
        // Same class signatures, different instances: image 0 of each split
        // has the same label but different pixels.
        assert_eq!(tr.labels[0], te.labels[0]);
        assert_ne!(tr.images[..768], te.images[..768]);
    }

    #[test]
    fn values_bounded() {
        let (tr, _) = generate(&SynthSpec { train_n: 16, test_n: 4, ..SYNTHTINY });
        assert!(tr.images.iter().all(|v| v.abs() <= 3.0));
    }

    #[test]
    fn classes_statistically_distinct() {
        // Mean image of class 0 differs from class 1 well beyond noise.
        let (tr, _) = generate(&SynthSpec { train_n: 512, test_n: 8, ..SYNTH10 });
        let ie = 3 * 16 * 16;
        let mut m0 = vec![0.0f64; ie];
        let mut m1 = vec![0.0f64; ie];
        let (mut n0, mut n1) = (0, 0);
        for i in 0..tr.len() {
            let img = &tr.images[i * ie..(i + 1) * ie];
            match tr.labels[i] {
                0 => {
                    for (a, &b) in m0.iter_mut().zip(img) {
                        *a += b as f64;
                    }
                    n0 += 1;
                }
                1 => {
                    for (a, &b) in m1.iter_mut().zip(img) {
                        *a += b as f64;
                    }
                    n1 += 1;
                }
                _ => {}
            }
        }
        let dist: f64 = m0
            .iter()
            .zip(&m1)
            .map(|(a, b)| (a / n0 as f64 - b / n1 as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        assert!(dist > 1.0, "class means too close: {dist}");
    }

    #[test]
    fn nchw_channel_planar_layout() {
        // With no sprites and no noise, channel `c` of every pixel is the
        // affine map `base*(1 + 0.5*color[c]) + 0.3*color[c]` of a shared
        // per-pixel base. Choosing color = [0, 1, -1] makes channel 0 equal
        // to the base, so the relation across *plane offsets* c*s*s pins the
        // NCHW layout: under HWC indexing these equalities would fail.
        let sig = ClassSig {
            gabor_theta: 0.7,
            gabor_freq: 2.0,
            gabor_amp: 0.8,
            color: [0.0, 1.0, -1.0],
            sprites: vec![],
        };
        let s = 8;
        let mut out = vec![0.0f32; 3 * s * s];
        render(&sig, s, &mut Rng::new(42), 0.0, &mut out);
        for p in 0..s * s {
            let base = out[p];
            assert!((out[s * s + p] - (base * 1.5 + 0.3)).abs() < 1e-5);
            assert!((out[2 * s * s + p] - (base * 0.5 - 0.3)).abs() < 1e-5);
        }
    }

    #[test]
    fn by_name_lookup() {
        assert!(by_name("synth10").is_some());
        assert!(by_name("synth100").is_some());
        assert!(by_name("synthtiny").is_some());
        assert!(by_name("cifar10").is_none());
    }
}
