//! Compile-only stub of the xla-rs API surface `cdnl`'s PJRT engine uses.
//!
//! Purpose: the `pjrt` cargo feature must not rot uncompiled just because
//! the real `xla` crate (a native XLA/PJRT binding) is absent from the
//! offline vendor set. This stub mirrors the exact types and signatures
//! `rust/src/runtime/engine.rs` and `rust/src/tensor.rs` call, so
//! `cargo check --features pjrt --all-targets` typechecks the engine in CI.
//! Every runtime entry point returns [`Error::Stub`] — opening the PJRT
//! backend against this stub fails loudly and immediately, it never
//! pretends to execute.
//!
//! To actually run artifacts, vendor the real xla-rs and point the root
//! `Cargo.toml`'s `xla = { path = ... }` dependency at it; this crate then
//! simply drops out of the build graph.

use std::path::Path;

/// The stub's only error: "this is not a real XLA".
#[derive(Debug)]
pub enum Error {
    Stub(&'static str),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let Error::Stub(what) = self;
        write!(
            f,
            "xla stub: {what} is unavailable (this build vendors the compile-only \
             xla stub; vendor the real xla-rs to execute artifacts)"
        )
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn stub<T>(what: &'static str) -> Result<T> {
    Err(Error::Stub(what))
}

/// Element types the artifact interface moves across the boundary.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for i32 {}

/// Host-side literal (stub: carries nothing).
#[derive(Debug, Default)]
pub struct Literal(());

impl Literal {
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal(())
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        stub("Literal::reshape")
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        stub("Literal::array_shape")
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        stub("Literal::to_vec")
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        stub("Literal::to_tuple")
    }
}

/// Array shape of a literal.
#[derive(Debug, Default)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Parsed HLO module proto.
#[derive(Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto> {
        stub("HloModuleProto::from_text_file")
    }
}

/// An XLA computation handle.
#[derive(Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// Device-resident buffer handle.
#[derive(Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        stub("PjRtBuffer::to_literal_sync")
    }
}

/// Compiled executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        stub("PjRtLoadedExecutable::execute")
    }

    pub fn execute_b<B>(&self, _args: &[B]) -> Result<Vec<Vec<PjRtBuffer>>> {
        stub("PjRtLoadedExecutable::execute_b")
    }
}

/// PJRT client handle.
#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    /// Creating the CPU client is the engine's first call, so a stub build
    /// fails here — before any artifact is touched.
    pub fn cpu() -> Result<PjRtClient> {
        stub("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        stub("PjRtClient::compile")
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        stub("PjRtClient::buffer_from_host_buffer")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_fails_loudly() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("xla stub"));
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let lit = Literal::vec1(&[1.0f32]);
        assert!(lit.reshape(&[1]).is_err());
        assert!(lit.to_vec::<f32>().is_err());
        assert!(lit.to_tuple().is_err());
        assert!(lit.array_shape().is_err());
        assert_eq!(ArrayShape::default().dims(), &[] as &[i64]);
    }
}
