//! Thin wrapper: `cargo bench --bench bench_fig4` runs the registered
//! `fig4` benchmark (see `rust/src/bench/suite/fig4.rs`) and writes its
//! report to `results/bench/BENCH_fig4.json`.

fn main() -> anyhow::Result<()> {
    cdnl::bench::bench_main("fig4")
}
