//! Thin wrapper: `cargo bench --bench bench_fig9` runs the registered
//! `fig9` benchmark (see `rust/src/bench/suite/fig9.rs`) and writes its
//! report to `results/bench/BENCH_fig9.json`.

fn main() -> anyhow::Result<()> {
    cdnl::bench::bench_main("fig9")
}
