//! Thin wrapper: `cargo bench --bench bench_table3` runs the registered
//! `table3` benchmark (see `rust/src/bench/suite/table3.rs`) and writes its
//! report to `results/bench/BENCH_table3.json`.

fn main() -> anyhow::Result<()> {
    cdnl::bench::bench_main("table3")
}
