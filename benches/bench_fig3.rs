//! Figure 3: Ours vs SENet on the ResNet18 backbone, in the paper's
//! baseline-agnostic metric: accuracy-at-budget / baseline accuracy.
//!
//! Shape criterion: Ours reaches the Pareto frontier on the CIFAR-100 and
//! TinyImageNet analogs, stays competitive on the CIFAR-10 analog.

#[path = "common/mod.rs"]
mod common;

use cdnl::methods::senet::{run_senet, SenetConfig};
use cdnl::metrics::{ascii_plot, print_table, write_csv, Series};
use cdnl::pipeline::Pipeline;

pub const BACKBONE: &str = "resnet";
pub const BENCH_ID: &str = "fig3";

// (also compiled as a module by bench_fig8, where this main is unused)
#[allow(dead_code)]
fn main() -> anyhow::Result<()> {
    run(BACKBONE, BENCH_ID)
}

pub fn run(backbone: &str, id: &str) -> anyhow::Result<()> {
    common::banner(id, "Ours vs SENet, relative-to-baseline accuracy");
    let engine = common::engine();

    let datasets: Vec<&str> = if common::full_mode() {
        vec!["synth10", "synth100", "synthtiny"]
    } else {
        vec!["synth100"]
    };
    let paper_budgets: &[f64] = &[50e3, 120e3, 180e3];
    let quick_n = 2;

    let mut rows = Vec::new();
    let mut csv = Vec::new();
    for dataset in datasets {
        let exp = common::experiment(dataset, backbone, false);
        let pl = Pipeline::new(&engine, exp)?;
        let total = pl.sess.info().total_relus();
        let size = pl.sess.info().image_size;
        let budgets: Vec<usize> = common::grid(paper_budgets, quick_n)
            .iter()
            .map(|&b| common::scale_budget(b, total, backbone, size))
            .collect();
        let baseline = pl.baseline()?;
        let base_acc = pl.test_acc(&baseline)?;

        let mut s_ours = Series::new("ours", vec![]);
        let mut s_senet = Series::new("senet", vec![]);
        for &budget in &budgets {
            let bref = common::bref_for(&pl.exp, total, budget);
            let ours = pl.bcd_cached(&pl.snl_ref(bref)?, budget)?;
            let ours_rel = pl.test_acc(&ours)? / base_acc;
            let mut st_se = baseline.clone();
            run_senet(&pl.sess, &mut st_se, &pl.train_ds, budget, &SenetConfig::default())?;
            let senet_rel = pl.test_acc(&st_se)? / base_acc;
            println!("[{dataset}] b={budget}: ours {ours_rel:.3} senet {senet_rel:.3} (rel. to {base_acc:.2}%)");
            s_ours.points.push((budget as f64, ours_rel));
            s_senet.points.push((budget as f64, senet_rel));
            rows.push(vec![
                dataset.to_string(),
                budget.to_string(),
                format!("{ours_rel:.3}"),
                format!("{senet_rel:.3}"),
            ]);
            csv.push(vec![
                dataset.to_string(),
                budget.to_string(),
                format!("{ours_rel:.4}"),
                format!("{senet_rel:.4}"),
                format!("{base_acc:.3}"),
            ]);
        }
        println!(
            "\n{}",
            ascii_plot(
                &format!("{id} ({dataset}) — acc/baseline vs budget"),
                &[s_ours, s_senet],
                60,
                12
            )
        );
    }
    print_table(
        &format!("Figure {id} — relative accuracy (acc@budget / baseline acc)"),
        &["dataset", "budget", "ours", "senet"],
        &rows,
    );
    write_csv(
        &common::results_csv(id),
        &["dataset", "budget", "ours_rel", "senet_rel", "baseline_acc"],
        &csv,
    )?;
    Ok(())
}
