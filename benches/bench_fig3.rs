//! Thin wrapper: `cargo bench --bench bench_fig3` runs the registered
//! `fig3` benchmark (see `rust/src/bench/suite/fig3.rs`) and writes its
//! report to `results/bench/BENCH_fig3.json`.

fn main() -> anyhow::Result<()> {
    cdnl::bench::bench_main("fig3")
}
