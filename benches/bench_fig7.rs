//! Thin wrapper: `cargo bench --bench bench_fig7` runs the registered
//! `fig7` benchmark (see `rust/src/bench/suite/fig7.rs`) and writes its
//! report to `results/bench/BENCH_fig7.json`.

fn main() -> anyhow::Result<()> {
    cdnl::bench::bench_main("fig7")
}
