//! Thin wrapper: `cargo bench --bench bench_perf_conv_lowered` runs the
//! registered `perf_conv_lowered` benchmark (see
//! `rust/src/bench/suite/perf_conv_lowered.rs`) and writes its report to
//! `results/bench/BENCH_perf_conv_lowered.json`.

fn main() -> anyhow::Result<()> {
    cdnl::bench::bench_main("perf_conv_lowered")
}
