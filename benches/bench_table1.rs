//! Thin wrapper: `cargo bench --bench bench_table1` runs the registered
//! `table1` benchmark (see `rust/src/bench/suite/table1.rs`) and writes its
//! report to `results/bench/BENCH_table1.json`.

fn main() -> anyhow::Result<()> {
    cdnl::bench::bench_main("table1")
}
