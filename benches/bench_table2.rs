//! Thin wrapper: `cargo bench --bench bench_table2` runs the registered
//! `table2` benchmark (see `rust/src/bench/suite/table2.rs`) and writes its
//! report to `results/bench/BENCH_table2.json`.

fn main() -> anyhow::Result<()> {
    cdnl::bench::bench_main("table2")
}
