//! Thin wrapper: `cargo bench --bench bench_fig11` runs the registered
//! `fig11` benchmark (see `rust/src/bench/suite/fig11.rs`) and writes its
//! report to `results/bench/BENCH_fig11.json`.

fn main() -> anyhow::Result<()> {
    cdnl::bench::bench_main("fig11")
}
