//! Thin wrapper: `cargo bench --bench bench_fig1` runs the registered
//! `fig1` benchmark (see `rust/src/bench/suite/fig1.rs`) and writes its
//! report to `results/bench/BENCH_fig1.json`.

fn main() -> anyhow::Result<()> {
    cdnl::bench::bench_main("fig1")
}
