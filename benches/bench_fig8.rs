//! Figure 8 (supplementary): Ours vs SENet on the WideResNet-22-8 backbone,
//! relative-to-baseline metric — same harness as Fig. 3, wide backbone.

#[path = "common/mod.rs"]
mod common;
#[path = "bench_fig3.rs"]
mod fig3;

fn main() -> anyhow::Result<()> {
    fig3::run("wrn", "fig8")
}
