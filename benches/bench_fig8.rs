//! Thin wrapper: `cargo bench --bench bench_fig8` runs the registered
//! `fig8` benchmark (see `rust/src/bench/suite/fig8.rs`) and writes its
//! report to `results/bench/BENCH_fig8.json`.

fn main() -> anyhow::Result<()> {
    cdnl::bench::bench_main("fig8")
}
