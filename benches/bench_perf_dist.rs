//! Thin wrapper: `cargo bench --bench bench_perf_dist` runs the registered
//! `perf_dist` benchmark (see `rust/src/bench/suite/perf_dist.rs`) and
//! writes its report to `results/bench/BENCH_perf_dist.json`.

fn main() -> anyhow::Result<()> {
    cdnl::bench::bench_main("perf_dist")
}
