//! Thin wrapper: `cargo bench --bench bench_fig6` runs the registered
//! `fig6` benchmark (see `rust/src/bench/suite/fig6.rs`) and writes its
//! report to `results/bench/BENCH_fig6.json`.

fn main() -> anyhow::Result<()> {
    cdnl::bench::bench_main("fig6")
}
