//! Thin wrapper: `cargo bench --bench bench_ablations` runs the registered
//! `ablations` benchmark (see `rust/src/bench/suite/ablations.rs`) and writes its
//! report to `results/bench/BENCH_ablations.json`.

fn main() -> anyhow::Result<()> {
    cdnl::bench::bench_main("ablations")
}
