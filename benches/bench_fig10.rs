//! Thin wrapper: `cargo bench --bench bench_fig10` runs the registered
//! `fig10` benchmark (see `rust/src/bench/suite/fig10.rs`) and writes its
//! report to `results/bench/BENCH_fig10.json`.

fn main() -> anyhow::Result<()> {
    cdnl::bench::bench_main("fig10")
}
