//! Thin wrapper: `cargo bench --bench bench_serve` runs the registered
//! `serve` benchmark (see `rust/src/bench/suite/serve.rs`) and writes its
//! report to `results/bench/BENCH_serve.json`.

fn main() -> anyhow::Result<()> {
    cdnl::bench::bench_main("serve")
}
