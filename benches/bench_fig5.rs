//! Thin wrapper: `cargo bench --bench bench_fig5` runs the registered
//! `fig5` benchmark (see `rust/src/bench/suite/fig5.rs`) and writes its
//! report to `results/bench/BENCH_fig5.json`.

fn main() -> anyhow::Result<()> {
    cdnl::bench::bench_main("fig5")
}
