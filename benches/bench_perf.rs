//! Thin wrapper: `cargo bench --bench bench_perf` runs the registered
//! `perf` benchmark (see `rust/src/bench/suite/perf.rs`) and writes its
//! report to `results/bench/BENCH_perf.json`.

fn main() -> anyhow::Result<()> {
    cdnl::bench::bench_main("perf")
}
